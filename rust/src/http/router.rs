//! Tiny path router: exact segments plus `:param` captures.

use super::{Request, Response};
use std::collections::HashMap;
use std::sync::Arc;

type RouteHandler = Arc<dyn Fn(&Request, &Params) -> Response + Send + Sync>;

/// Captured `:param` values for one match.
pub type Params = HashMap<String, String>;

struct Route {
    method: String,
    segments: Vec<Segment>,
    handler: RouteHandler,
}

enum Segment {
    Literal(String),
    Param(String),
}

/// Method+path router. Longest-registered-first is unnecessary: patterns
/// here are disjoint; first match wins.
#[derive(Default)]
pub struct Router {
    routes: Vec<Route>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Register a route, e.g. `router.add("GET", "/models/:name", h)`.
    pub fn add<F>(&mut self, method: &str, pattern: &str, handler: F)
    where
        F: Fn(&Request, &Params) -> Response + Send + Sync + 'static,
    {
        let segments = pattern
            .trim_matches('/')
            .split('/')
            .filter(|s| !s.is_empty())
            .map(|s| {
                if let Some(name) = s.strip_prefix(':') {
                    Segment::Param(name.to_string())
                } else {
                    Segment::Literal(s.to_string())
                }
            })
            .collect();
        self.routes.push(Route {
            method: method.to_uppercase(),
            segments,
            handler: Arc::new(handler),
        });
    }

    /// Dispatch a request; 404 when no pattern matches, 405 when the path
    /// matches but the method doesn't.
    pub fn dispatch(&self, req: &Request) -> Response {
        let path_segments: Vec<&str> = req
            .path
            .trim_matches('/')
            .split('/')
            .filter(|s| !s.is_empty())
            .collect();
        let mut path_matched = false;
        for route in &self.routes {
            if let Some(params) = match_segments(&route.segments, &path_segments) {
                if route.method == req.method {
                    return (route.handler)(req, &params);
                }
                path_matched = true;
            }
        }
        if path_matched {
            Response::error(405, "method not allowed")
        } else {
            Response::not_found()
        }
    }

    /// Wrap into a server handler.
    pub fn into_handler(self) -> super::server::Handler {
        let router = Arc::new(self);
        Arc::new(move |req: &Request| router.dispatch(req))
    }
}

fn match_segments(pattern: &[Segment], path: &[&str]) -> Option<Params> {
    if pattern.len() != path.len() {
        return None;
    }
    let mut params = Params::new();
    for (seg, part) in pattern.iter().zip(path) {
        match seg {
            Segment::Literal(lit) if lit == part => {}
            Segment::Literal(_) => return None,
            Segment::Param(name) => {
                params.insert(name.clone(), part.to_string());
            }
        }
    }
    Some(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        let mut r = Router::new();
        r.add("GET", "/healthz", |_, _| Response::text(200, "ok"));
        r.add("GET", "/models/:name", |_, p| {
            Response::text(200, &format!("model={}", p["name"]))
        });
        r.add("POST", "/predict", |req, _| {
            Response::text(200, &format!("len={}", req.body.len()))
        });
        r
    }

    fn get(path: &str) -> Request {
        Request::new("GET", path, Vec::new())
    }

    #[test]
    fn exact_match() {
        assert_eq!(router().dispatch(&get("/healthz")).status, 200);
        assert_eq!(router().dispatch(&get("/healthz/")).status, 200);
    }

    #[test]
    fn param_capture() {
        let resp = router().dispatch(&get("/models/cnn_s"));
        assert_eq!(resp.body, b"model=cnn_s");
    }

    #[test]
    fn not_found_vs_method_not_allowed() {
        assert_eq!(router().dispatch(&get("/nope")).status, 404);
        assert_eq!(router().dispatch(&get("/predict")).status, 405);
        assert_eq!(
            router().dispatch(&Request::new("POST", "/predict", b"xy".to_vec())).body,
            b"len=2"
        );
    }

    #[test]
    fn length_mismatch_no_match() {
        assert_eq!(router().dispatch(&get("/models")).status, 404);
        assert_eq!(router().dispatch(&get("/models/a/b")).status, 404);
    }
}
