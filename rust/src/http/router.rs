//! Path router + middleware stack.
//!
//! Routing: exact segments plus `:param` captures, with path segments
//! percent-decoded **before** matching (so `/v1/models/cnn%5Fs/predict`
//! captures `cnn_s`).
//!
//! Middleware (applied around every dispatch, in order):
//! 1. request-id — echo the client's `x-request-id` or generate one; the
//!    id is set on the response and handed to observers;
//! 2. panic guard — a panicking handler renders a uniform 500 instead of
//!    poisoning the connection worker;
//! 3. uniform JSON error rendering — unmatched routes answer with the
//!    `{"error": {"code", "message"}}` envelope (`route.not_found` /
//!    `route.method_not_allowed`);
//! 4. observers — per-request hooks ([`RouterObserver`]) for per-route
//!    latency/metrics recording and access logging.

use super::{Request, Response};
use crate::util::Stopwatch;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A route handler behind an `Arc` so multiple patterns (e.g. a `/v1`
/// route and its legacy alias) can share one implementation.
pub type RouteHandler = Arc<dyn Fn(&Request, &Params) -> Response + Send + Sync>;

/// Captured `:param` values for one match (percent-decoded).
pub type Params = HashMap<String, String>;

/// One completed request, as seen by middleware observers.
pub struct RequestInfo<'a> {
    pub request_id: &'a str,
    pub method: &'a str,
    pub path: &'a str,
    /// Matched route pattern (`None` when no route matched).
    pub route: Option<&'a str>,
    pub status: u16,
    pub latency_micros: u64,
}

/// Middleware hook invoked once per request, after the response is built.
pub trait RouterObserver: Send + Sync {
    fn on_request(&self, info: &RequestInfo<'_>);
}

/// Access-log middleware: one line per request on stderr.
pub struct AccessLog;

impl RouterObserver for AccessLog {
    fn on_request(&self, info: &RequestInfo<'_>) {
        eprintln!(
            "{} {} {} -> {} {}us rid={}",
            info.method,
            info.path,
            info.route.unwrap_or("-"),
            info.status,
            info.latency_micros,
            info.request_id,
        );
    }
}

struct Route {
    method: String,
    pattern: String,
    segments: Vec<Segment>,
    handler: RouteHandler,
}

enum Segment {
    Literal(String),
    Param(String),
}

/// Method+path router. Longest-registered-first is unnecessary: patterns
/// here are disjoint; first match wins.
#[derive(Default)]
pub struct Router {
    routes: Vec<Route>,
    observers: Vec<Arc<dyn RouterObserver>>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Register a route, e.g. `router.add("GET", "/models/:name", h)`.
    pub fn add<F>(&mut self, method: &str, pattern: &str, handler: F)
    where
        F: Fn(&Request, &Params) -> Response + Send + Sync + 'static,
    {
        self.add_shared(method, pattern, Arc::new(handler));
    }

    /// Register a shared handler under one more pattern (route aliasing).
    pub fn add_shared(&mut self, method: &str, pattern: &str, handler: RouteHandler) {
        let segments = pattern
            .trim_matches('/')
            .split('/')
            .filter(|s| !s.is_empty())
            .map(|s| {
                if let Some(name) = s.strip_prefix(':') {
                    Segment::Param(name.to_string())
                } else {
                    Segment::Literal(s.to_string())
                }
            })
            .collect();
        self.routes.push(Route {
            method: method.to_uppercase(),
            pattern: pattern.to_string(),
            segments,
            handler,
        });
    }

    /// Register a middleware observer (metrics recorder, access log, ...).
    pub fn observe(&mut self, observer: Arc<dyn RouterObserver>) {
        self.observers.push(observer);
    }

    /// Dispatch a request through the middleware stack.
    pub fn dispatch(&self, req: &Request) -> Response {
        let sw = Stopwatch::start();
        let request_id = req
            .header("x-request-id")
            .map(str::to_string)
            .unwrap_or_else(next_request_id);
        let (mut resp, route) = self.route(req);
        resp.headers
            .push(("x-request-id".to_string(), request_id.clone()));
        let info = RequestInfo {
            request_id: &request_id,
            method: &req.method,
            path: &req.path,
            route,
            status: resp.status,
            latency_micros: sw.elapsed_micros(),
        };
        for obs in &self.observers {
            obs.on_request(&info);
        }
        resp
    }

    /// Core routing: 404/405 render the uniform JSON error envelope (a 405
    /// carries an `Allow` header listing every method registered for the
    /// path); a panicking handler is caught and rendered as a 500.
    fn route(&self, req: &Request) -> (Response, Option<&str>) {
        let path_segments: Vec<String> = req
            .path
            .trim_matches('/')
            .split('/')
            .filter(|s| !s.is_empty())
            .map(percent_decode)
            .collect();
        // Methods registered for this path (only populated until a full
        // match dispatches).
        let mut allowed: Vec<&str> = Vec::new();
        for route in &self.routes {
            if let Some(params) = match_segments(&route.segments, &path_segments) {
                if route.method == req.method {
                    let resp = catch_unwind(AssertUnwindSafe(|| (route.handler)(req, &params)))
                        .unwrap_or_else(|_| {
                            Response::coded_error(500, "internal", "handler panicked")
                        });
                    return (resp, Some(route.pattern.as_str()));
                }
                if !allowed.contains(&route.method.as_str()) {
                    allowed.push(&route.method);
                }
            }
        }
        if !allowed.is_empty() {
            allowed.sort_unstable();
            let allow = allowed.join(", ");
            let mut resp = Response::coded_error(
                405,
                "route.method_not_allowed",
                &format!("method {} not allowed (allow: {allow})", req.method),
            );
            resp.headers.push(("allow".to_string(), allow));
            (resp, None)
        } else {
            (Response::coded_error(404, "route.not_found", "no such route"), None)
        }
    }

    /// Wrap into a server handler.
    pub fn into_handler(self) -> super::server::Handler {
        let router = Arc::new(self);
        Arc::new(move |req: &Request| router.dispatch(req))
    }
}

fn match_segments(pattern: &[Segment], path: &[String]) -> Option<Params> {
    if pattern.len() != path.len() {
        return None;
    }
    let mut params = Params::new();
    for (seg, part) in pattern.iter().zip(path) {
        match seg {
            Segment::Literal(lit) if lit == part => {}
            Segment::Literal(_) => return None,
            Segment::Param(name) => {
                params.insert(name.clone(), part.clone());
            }
        }
    }
    Some(params)
}

/// Decode `%XX` escapes in one path segment. `+` is NOT special in paths
/// (that's a query-string convention); malformed escapes pass through
/// verbatim rather than failing the whole request.
pub fn percent_decode(segment: &str) -> String {
    let bytes = segment.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' && i + 2 < bytes.len() {
            if let (Some(hi), Some(lo)) = (hex_val(bytes[i + 1]), hex_val(bytes[i + 2])) {
                out.push(hi * 16 + lo);
                i += 3;
                continue;
            }
        }
        out.push(bytes[i]);
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn hex_val(b: u8) -> Option<u8> {
    match b {
        b'0'..=b'9' => Some(b - b'0'),
        b'a'..=b'f' => Some(b - b'a' + 10),
        b'A'..=b'F' => Some(b - b'A' + 10),
        _ => None,
    }
}

static REQUEST_SEQ: AtomicU64 = AtomicU64::new(0);

/// Process-unique request id: `<pid hex>-<sequence>`.
fn next_request_id() -> String {
    format!(
        "{:x}-{:06}",
        std::process::id(),
        REQUEST_SEQ.fetch_add(1, Ordering::Relaxed)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    fn router() -> Router {
        let mut r = Router::new();
        r.add("GET", "/healthz", |_, _| Response::text(200, "ok"));
        r.add("GET", "/models/:name", |_, p| {
            Response::text(200, &format!("model={}", p["name"]))
        });
        r.add("POST", "/predict", |req, _| {
            Response::text(200, &format!("len={}", req.body.len()))
        });
        r
    }

    fn get(path: &str) -> Request {
        Request::new("GET", path, Vec::new())
    }

    #[test]
    fn exact_match() {
        assert_eq!(router().dispatch(&get("/healthz")).status, 200);
        assert_eq!(router().dispatch(&get("/healthz/")).status, 200);
    }

    #[test]
    fn param_capture() {
        let resp = router().dispatch(&get("/models/cnn_s"));
        assert_eq!(resp.body, b"model=cnn_s");
    }

    #[test]
    fn not_found_vs_method_not_allowed() {
        assert_eq!(router().dispatch(&get("/nope")).status, 404);
        let resp = router().dispatch(&get("/predict"));
        assert_eq!(resp.status, 405);
        assert_eq!(resp.header("allow"), Some("POST"));
        assert_eq!(
            router().dispatch(&Request::new("POST", "/predict", b"xy".to_vec())).body,
            b"len=2"
        );
        // A 404 carries no Allow header — nothing is allowed on that path.
        assert!(router().dispatch(&get("/nope")).header("allow").is_none());
    }

    #[test]
    fn allow_header_lists_every_method_on_v1_and_v2_routes() {
        let mut r = Router::new();
        r.add("PUT", "/v1/ensemble", |_, _| Response::text(200, "put"));
        r.add("GET", "/v1/ensemble", |_, _| Response::text(200, "get"));
        r.add("POST", "/v2/models/:name/infer", |_, _| Response::text(200, "infer"));

        // Multiple methods on one path: all listed, sorted, deduped.
        let resp = r.dispatch(&Request::new("DELETE", "/v1/ensemble", Vec::new()));
        assert_eq!(resp.status, 405);
        assert_eq!(resp.header("allow"), Some("GET, PUT"));
        assert_eq!(
            resp.json_body().unwrap().path(&["error", "code"]).unwrap().as_str(),
            Some("route.method_not_allowed")
        );

        // Param routes 405 correctly too (GET on a POST-only /v2 route).
        let resp = r.dispatch(&get("/v2/models/mlp/infer"));
        assert_eq!(resp.status, 405);
        assert_eq!(resp.header("allow"), Some("POST"));

        // Matching methods still dispatch.
        assert_eq!(r.dispatch(&Request::new("PUT", "/v1/ensemble", Vec::new())).body, b"put");
        assert_eq!(
            r.dispatch(&Request::new("POST", "/v2/models/x/infer", Vec::new())).body,
            b"infer"
        );
    }

    #[test]
    fn length_mismatch_no_match() {
        assert_eq!(router().dispatch(&get("/models")).status, 404);
        assert_eq!(router().dispatch(&get("/models/a/b")).status, 404);
    }

    #[test]
    fn unmatched_routes_render_coded_errors() {
        let v = router().dispatch(&get("/nope")).json_body().unwrap();
        assert_eq!(
            v.path(&["error", "code"]).unwrap().as_str(),
            Some("route.not_found")
        );
        let v = router().dispatch(&get("/predict")).json_body().unwrap();
        assert_eq!(
            v.path(&["error", "code"]).unwrap().as_str(),
            Some("route.method_not_allowed")
        );
    }

    #[test]
    fn percent_decoded_path_segments() {
        // Encoded characters inside a :param capture decode before capture.
        assert_eq!(router().dispatch(&get("/models/cnn%5Fs")).body, b"model=cnn_s");
        assert_eq!(router().dispatch(&get("/models/a%20b")).body, b"model=a b");
        // Literal segments decode too.
        assert_eq!(router().dispatch(&get("/%68ealthz")).status, 200);
        // Malformed escapes pass through verbatim.
        assert_eq!(router().dispatch(&get("/models/a%2")).body, b"model=a%2");
        assert_eq!(router().dispatch(&get("/models/a%zz")).body, b"model=a%zz");
    }

    #[test]
    fn request_id_generated_and_echoed() {
        let r = router();
        let resp = r.dispatch(&get("/healthz"));
        assert!(resp.header("x-request-id").is_some());
        let mut req = get("/healthz");
        req.headers.push(("x-request-id".into(), "rid-42".into()));
        assert_eq!(r.dispatch(&req).header("x-request-id"), Some("rid-42"));
    }

    #[test]
    fn observers_see_route_and_status() {
        struct Capture(Mutex<Vec<(Option<String>, u16)>>);
        impl RouterObserver for Capture {
            fn on_request(&self, info: &RequestInfo<'_>) {
                self.0
                    .lock()
                    .unwrap()
                    .push((info.route.map(str::to_string), info.status));
            }
        }
        let capture = Arc::new(Capture(Mutex::new(Vec::new())));
        let mut r = router();
        r.observe(Arc::clone(&capture) as Arc<dyn RouterObserver>);
        r.dispatch(&get("/models/x"));
        r.dispatch(&get("/nope"));
        let seen = capture.0.lock().unwrap();
        assert_eq!(seen[0], (Some("/models/:name".to_string()), 200));
        assert_eq!(seen[1], (None, 404));
    }

    #[test]
    fn panicking_handler_renders_500() {
        let mut r = Router::new();
        r.add("GET", "/boom", |_, _| panic!("kaboom"));
        let resp = r.dispatch(&get("/boom"));
        assert_eq!(resp.status, 500);
        assert_eq!(
            resp.json_body().unwrap().path(&["error", "code"]).unwrap().as_str(),
            Some("internal")
        );
    }

    #[test]
    fn shared_handler_aliases() {
        let mut r = Router::new();
        let h: RouteHandler = Arc::new(|_, _| Response::text(200, "hi"));
        r.add_shared("GET", "/v1/hello", Arc::clone(&h));
        r.add_shared("GET", "/hello", h);
        assert_eq!(r.dispatch(&get("/v1/hello")).body, b"hi");
        assert_eq!(r.dispatch(&get("/hello")).body, b"hi");
    }
}
