//! The device executor: one thread owning one `PjRtClient` and every
//! compiled (model × bucket) executable — the Rust incarnation of the
//! paper's `fmodels` shared-memory ensemble (§2.2).
//!
//! xla handles are `!Send`, so all PJRT work happens on this thread;
//! request threads hold a cheap [`ExecutorHandle`] (`Clone + Send + Sync`)
//! and submit [`ExecRequest`]s over a channel. Device work is therefore
//! serialized exactly like N models sharing one GPU stream.

use super::manifest::Manifest;
use super::tensor::{self, TensorView};
use crate::chaos;
use crate::util::Stopwatch;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

/// Typed marker for a device-worker crash. Travels through `anyhow` so
/// the coordinator can recover it into the `exec.worker_crashed` taxonomy
/// row instead of an untyped 500 — the runtime layer itself stays
/// coordinator-free.
#[derive(Debug, Clone)]
pub struct WorkerCrashed {
    pub detail: String,
}

impl WorkerCrashed {
    pub fn new(detail: impl Into<String>) -> WorkerCrashed {
        WorkerCrashed {
            detail: detail.into(),
        }
    }
}

impl fmt::Display for WorkerCrashed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "device worker crashed: {}", self.detail)
    }
}

impl std::error::Error for WorkerCrashed {}

/// One inference job for a single model.
#[derive(Debug, Clone)]
pub struct ExecRequest {
    pub model: String,
    /// True (unpadded) batch size; must be ≥ 1 and ≤ the model's max bucket.
    pub batch: usize,
    /// Row-major `(batch, H, W, C)` input, already normalized. A shared
    /// view: N models × chunks all reference one request buffer.
    pub data: TensorView,
}

/// Result of one inference job.
#[derive(Debug, Clone)]
pub struct ExecResponse {
    /// Row-major `(batch, num_classes)` logits, truncated to the true batch.
    pub logits: Vec<f32>,
    /// Bucket the job actually ran on (≥ batch).
    pub bucket: usize,
    /// Time spent queued behind other device work.
    pub queue_micros: u64,
    /// Device execution time (pad + literal + execute + readback).
    pub exec_micros: u64,
}

/// Pairs the submit-side `in_flight_rows` increment on EVERY exit path:
/// executed, dropped with a crashed worker's queue, or bounced off a
/// closed channel — the load signal can never leak rows.
struct RowsGuard {
    counter: Arc<AtomicUsize>,
    rows: usize,
}

impl Drop for RowsGuard {
    fn drop(&mut self) {
        self.counter.fetch_sub(self.rows, Ordering::Relaxed);
    }
}

struct Job {
    req: ExecRequest,
    enqueued: Stopwatch,
    reply: mpsc::Sender<Result<ExecResponse>>,
    rows: RowsGuard,
}

/// Channel protocol to the device thread. An explicit `Shutdown` message
/// (rather than relying on channel closure) lets `Executor::drop` stop the
/// thread even while cloned `ExecutorHandle`s still hold senders.
/// `Load`/`Unload` are the runtime model-lifecycle messages behind the
/// `/v1` control plane: compile a model's artifacts into (or evict them
/// from) this device without restarting the server.
enum Msg {
    Job(Job),
    Load {
        model: String,
        reply: mpsc::Sender<Result<bool>>,
    },
    Unload {
        model: String,
        reply: mpsc::Sender<Result<bool>>,
    },
    Shutdown,
}

/// Which artifacts an executor loads (subset support is what lets the
/// benches build "one model per device" baselines).
#[derive(Debug, Clone, Default)]
pub struct ExecutorOptions {
    /// Models to load; `None` = every model in the manifest.
    pub models: Option<Vec<String>>,
    /// Buckets to compile; `None` = every bucket in the manifest.
    pub buckets: Option<Vec<usize>>,
    /// Verify artifact SHA-256 against the manifest before loading
    /// (applies to boot-time compilation AND runtime loads).
    pub verify_sha: bool,
    /// Verify artifact SHA-256 only on runtime `load_model` requests —
    /// for callers that already verified everything at startup and don't
    /// want boot-time compilation to hash each artifact again.
    pub verify_on_load: bool,
    /// Run one warmup execution per executable after compiling.
    pub warmup: bool,
}

/// Cloneable, thread-safe handle to a device executor.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: mpsc::Sender<Msg>,
    manifest: Arc<Manifest>,
    /// Rows submitted to this device but not yet executed — the load
    /// signal behind the pool's least-loaded dispatch. Incremented at
    /// submit, decremented by the device thread when the job finishes.
    in_flight_rows: Arc<AtomicUsize>,
    /// Cleared by the device thread when it crashes; the pool's dispatch
    /// skips unhealthy executors and its supervisor respawns them.
    healthy: Arc<AtomicBool>,
}

impl ExecutorHandle {
    /// Blocking single-model inference.
    pub fn infer(&self, req: ExecRequest) -> Result<ExecResponse> {
        self.infer_async(req)?
            .recv()
            .map_err(|_| anyhow::Error::new(WorkerCrashed::new("executor dropped the job")))?
    }

    /// Submit without waiting; returns the reply receiver. Lets the
    /// ensemble overlap N model submissions before collecting.
    pub fn infer_async(&self, req: ExecRequest) -> Result<mpsc::Receiver<Result<ExecResponse>>> {
        if let Some(kind) = chaos::decide(chaos::EXEC_SUBMIT) {
            match kind {
                chaos::FaultKind::Panic => panic!("chaos: injected panic at exec.submit"),
                _ => return Err(anyhow!("chaos: injected failure at exec.submit")),
            }
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        // Count the rows BEFORE the send so concurrent least-loaded picks
        // already see this job; the guard travels with the job, so the
        // decrement pairs on every path (executed, crashed, or bounced).
        let rows = req.batch;
        self.in_flight_rows.fetch_add(rows, Ordering::Relaxed);
        let guard = RowsGuard {
            counter: Arc::clone(&self.in_flight_rows),
            rows,
        };
        if self
            .tx
            .send(Msg::Job(Job {
                req,
                enqueued: Stopwatch::start(),
                reply: reply_tx,
                rows: guard,
            }))
            .is_err()
        {
            // The SendError dropped the job (and its guard) for us.
            return Err(anyhow::Error::new(WorkerCrashed::new(
                "executor thread is gone",
            )));
        }
        Ok(reply_rx)
    }

    /// False once the device thread has crashed (until a respawn replaces
    /// this executor).
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::Relaxed)
    }

    /// Rows currently submitted-but-unfinished on this device.
    pub fn in_flight_rows(&self) -> usize {
        self.in_flight_rows.load(Ordering::Relaxed)
    }

    /// Compile `model`'s artifacts into this device at runtime (subject to
    /// the executor's bucket filter and SHA verification options).
    /// `Ok(true)` = newly compiled, `Ok(false)` = already fully loaded.
    pub fn load_model(&self, model: &str) -> Result<bool> {
        self.load_model_async(model)?
            .recv()
            .map_err(|_| anyhow!("executor dropped the load request"))?
    }

    /// Submit a runtime load without waiting; returns the reply receiver.
    /// The pool broadcasts loads this way so W workers compile
    /// concurrently (boot-parity) instead of W× sequentially.
    pub fn load_model_async(&self, model: &str) -> Result<mpsc::Receiver<Result<bool>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Load {
                model: model.to_string(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("executor thread is gone"))?;
        Ok(reply_rx)
    }

    /// Evict every executable of `model` from this device, freeing its
    /// memory. `Ok(true)` = something was evicted.
    pub fn unload_model(&self, model: &str) -> Result<bool> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Unload {
                model: model.to_string(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("executor thread is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("executor dropped the unload request"))?
    }

    pub fn manifest(&self) -> &Arc<Manifest> {
        &self.manifest
    }
}

/// Owns the executor thread; dropping shuts it down (after queued work).
pub struct Executor {
    handle: ExecutorHandle,
    thread: Option<thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn the device thread, compile all selected artifacts, and block
    /// until the device is ready (or compilation failed).
    pub fn spawn(manifest: Arc<Manifest>, opts: ExecutorOptions) -> Result<Executor> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let m = Arc::clone(&manifest);
        let in_flight_rows = Arc::new(AtomicUsize::new(0));
        let healthy = Arc::new(AtomicBool::new(true));
        let healthy2 = Arc::clone(&healthy);
        let thread = thread::Builder::new()
            .name("flexserve-device".into())
            .spawn(move || device_thread(m, opts, rx, ready_tx, healthy2))
            .context("spawning device executor thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("device thread died during startup"))??;
        Ok(Executor {
            handle: ExecutorHandle {
                tx,
                manifest,
                in_flight_rows,
                healthy,
            },
            thread: Some(thread),
        })
    }

    pub fn handle(&self) -> ExecutorHandle {
        self.handle.clone()
    }

    /// Rows currently submitted-but-unfinished on this device.
    pub fn in_flight_rows(&self) -> usize {
        self.handle.in_flight_rows()
    }

    /// False once the device thread has crashed.
    pub fn is_healthy(&self) -> bool {
        self.handle.is_healthy()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        // Explicit shutdown: cloned handles may still hold senders, so
        // channel closure alone would never arrive.
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Compiled executables, nested `model name → bucket → executable`. The
/// inner map is ordered so "smallest loaded bucket that fits" is a range
/// query, and the outer map is queried with a borrowed `&str` — dispatch
/// allocates no `(String, bucket)` key per request.
type ExecutableMap = HashMap<String, BTreeMap<usize, xla::PjRtLoadedExecutable>>;

/// Body of the device thread: compile everything, then serve jobs forever.
fn device_thread(
    manifest: Arc<Manifest>,
    opts: ExecutorOptions,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<()>>,
    healthy: Arc<AtomicBool>,
) {
    let setup = (|| -> Result<(xla::PjRtClient, ExecutableMap)> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = ExecutableMap::new();
        for model in &manifest.models {
            if let Some(want) = &opts.models {
                if !want.contains(&model.name) {
                    continue;
                }
            }
            compile_model(&client, &manifest, &opts, model, &mut executables)?;
        }
        if executables.is_empty() {
            bail!("executor loaded zero executables (model/bucket filter too strict?)");
        }
        Ok((client, executables))
    })();

    let (client, mut executables) = match setup {
        Ok(pair) => {
            let _ = ready.send(Ok(()));
            pair
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    // Serve until shutdown (or every handle is dropped).
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Job(job) => {
                let Job {
                    req,
                    enqueued,
                    reply,
                    rows,
                } = job;
                let queue_micros = enqueued.elapsed_micros();
                // Supervised execution: a panic anywhere under execute_job
                // (or an injected chaos panic) must not abandon the reply
                // channel — callers would hang forever on recv().
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    if let Some(kind) = chaos::decide(chaos::EXEC_DEVICE) {
                        match kind {
                            chaos::FaultKind::Panic => {
                                panic!("chaos: injected panic at exec.device")
                            }
                            _ => bail!("chaos: injected failure at exec.device"),
                        }
                    }
                    execute_job(&executables, &manifest, &req)
                }));
                // Whatever happened, the rows are no longer ahead of anyone.
                drop(rows);
                match outcome {
                    Ok(result) => {
                        let result = result.map(|(logits, bucket, exec_micros)| ExecResponse {
                            logits,
                            bucket,
                            queue_micros,
                            exec_micros,
                        });
                        let _ = reply.send(result); // receiver may have timed out; fine
                    }
                    Err(panic) => {
                        // The worker is poisoned: fail this job and every
                        // queued message with a typed error, flag the
                        // executor unhealthy (dispatch skips it, the pool
                        // supervisor respawns it), and exit the thread.
                        healthy.store(false, Ordering::Relaxed);
                        let detail = panic_message(&panic);
                        let _ = reply.send(Err(WorkerCrashed::new(&detail).into()));
                        fail_queued(&rx, &detail);
                        return;
                    }
                }
            }
            Msg::Load { model, reply } => {
                let result = (|| -> Result<bool> {
                    let entry = manifest
                        .model(&model)
                        .ok_or_else(|| anyhow!("unknown model '{model}'"))?;
                    // Runtime admission re-verifies provenance when either
                    // flag asks for it (startup verification doesn't cover
                    // artifacts that changed on disk since boot).
                    let load_opts = ExecutorOptions {
                        verify_sha: opts.verify_sha || opts.verify_on_load,
                        ..opts.clone()
                    };
                    let added =
                        compile_model(&client, &manifest, &load_opts, entry, &mut executables)?;
                    // Inner bucket maps are created only on insert, so
                    // presence of the key means ≥ 1 executable.
                    if !executables.contains_key(&model) {
                        bail!("bucket filter selects no artifacts for '{model}'");
                    }
                    Ok(added > 0)
                })();
                let _ = reply.send(result);
            }
            Msg::Unload { model, reply } => {
                let had = executables.remove(&model).is_some();
                let _ = reply.send(Ok(had));
            }
            Msg::Shutdown => break,
        }
    }
}

/// Best-effort panic payload → human detail (panics carry `&str` or
/// `String` in practice; anything else gets a fixed label).
fn panic_message(panic: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic in device worker".to_string()
    }
}

/// Drain everything already queued behind a crashed worker, replying a
/// typed error so no caller blocks on a dead thread. Each dropped Job's
/// RowsGuard retires its in-flight rows.
fn fail_queued(rx: &mpsc::Receiver<Msg>, detail: &str) {
    while let Ok(msg) = rx.try_recv() {
        match msg {
            Msg::Job(job) => {
                let _ = job.reply.send(Err(WorkerCrashed::new(detail).into()));
            }
            Msg::Load { reply, .. } => {
                let _ = reply.send(Err(WorkerCrashed::new(detail).into()));
            }
            Msg::Unload { reply, .. } => {
                let _ = reply.send(Err(WorkerCrashed::new(detail).into()));
            }
            Msg::Shutdown => {}
        }
    }
}

/// Compile (and optionally warm up) every selected bucket of one model
/// into `executables`, verifying provenance when the options say so.
/// Already-compiled buckets are skipped; returns how many were added.
fn compile_model(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    opts: &ExecutorOptions,
    model: &crate::runtime::ModelEntry,
    executables: &mut ExecutableMap,
) -> Result<usize> {
    let mut added = 0;
    for art in &model.buckets {
        if let Some(want) = &opts.buckets {
            if !want.contains(&art.bucket) {
                continue;
            }
        }
        if executables
            .get(&model.name)
            .is_some_and(|b| b.contains_key(&art.bucket))
        {
            continue;
        }
        if opts.verify_sha {
            manifest
                .verify_artifact(art)
                .with_context(|| format!("model {}", model.name))?;
        }
        let path = manifest.artifact_path(art);
        // HLO TEXT interchange: see aot.py / DESIGN.md — serialized
        // protos from jax>=0.5 are rejected by xla_extension 0.5.1.
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", art.file))?;
        if opts.warmup {
            let zeros = vec![0.0f32; art.bucket * manifest.sample_elems()];
            run_one(&exe, &zeros, art.bucket, manifest)
                .with_context(|| format!("warmup {} b{}", model.name, art.bucket))?;
        }
        executables
            .entry(model.name.clone())
            .or_default()
            .insert(art.bucket, exe);
        added += 1;
    }
    Ok(added)
}

fn execute_job(
    executables: &ExecutableMap,
    manifest: &Manifest,
    req: &ExecRequest,
) -> Result<(Vec<f32>, usize, u64)> {
    let elems = manifest.sample_elems();
    if req.batch == 0 {
        bail!("empty batch");
    }
    if req.data.len() != req.batch * elems {
        bail!(
            "payload size {} != batch {} x {} elems",
            req.data.len(),
            req.batch,
            elems
        );
    }
    let model = manifest
        .model(&req.model)
        .ok_or_else(|| anyhow!("unknown model '{}'", req.model))?;
    // Borrowed `&str` lookup: the dispatch loop allocates no key strings.
    let loaded = executables
        .get(req.model.as_str())
        .ok_or_else(|| anyhow!("model '{}' has no loaded executables (unloaded?)", req.model))?;
    // Smallest *loaded* bucket that fits (the inner map is bucket-ordered).
    let (&bucket, exe) = loaded.range(req.batch..).next().ok_or_else(|| {
        anyhow!(
            "batch {} exceeds largest loaded bucket for '{}' (max {})",
            req.batch,
            req.model,
            model.max_bucket()
        )
    })?;

    let sw = Stopwatch::start();
    let padded;
    let feed: &[f32] = if bucket == req.batch {
        req.data.as_slice()
    } else {
        padded = tensor::pad_batch(&req.data, req.batch, bucket, elems);
        &padded
    };
    let logits_full = run_one(exe, feed, bucket, manifest)?;
    let exec_micros = sw.elapsed_micros();
    let logits = tensor::truncate_batch(logits_full, req.batch, manifest.num_classes());
    Ok((logits, bucket, exec_micros))
}

/// Execute one bucket-shaped forward: literal in, tuple1 literal out.
fn run_one(
    exe: &xla::PjRtLoadedExecutable,
    feed: &[f32],
    bucket: usize,
    manifest: &Manifest,
) -> Result<Vec<f32>> {
    // Single-copy literal creation straight into the batched shape
    // (§Perf L3#3: vec1+reshape copied the payload twice).
    let mut dims: Vec<usize> = vec![bucket];
    dims.extend(&manifest.input_shape);
    let bytes = unsafe {
        std::slice::from_raw_parts(feed.as_ptr() as *const u8, std::mem::size_of_val(feed))
    };
    let input =
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &dims, bytes)
            .context("creating input literal")?;
    let result = exe
        .execute::<xla::Literal>(&[input])
        .context("PJRT execute")?[0][0]
        .to_literal_sync()
        .context("device→host readback")?;
    // aot.py lowers with return_tuple=True → 1-tuple of logits.
    let logits = result.to_tuple1().context("unwrapping output tuple")?;
    logits.to_vec::<f32>().context("logits to f32 vec")
}

#[cfg(test)]
mod tests {
    // Executor tests that need real artifacts live in rust/tests/ (they
    // require `make artifacts` to have run); here we only test the pieces
    // that don't need a device.
    use super::*;

    #[test]
    fn options_default_loads_everything() {
        let o = ExecutorOptions::default();
        assert!(o.models.is_none());
        assert!(o.buckets.is_none());
        assert!(!o.verify_sha);
    }

    #[test]
    fn worker_crashed_is_typed_through_anyhow() {
        let e: anyhow::Error = WorkerCrashed::new("boom").into();
        assert_eq!(e.downcast_ref::<WorkerCrashed>().unwrap().detail, "boom");
        assert!(e.to_string().contains("device worker crashed: boom"));
    }

    #[test]
    fn fail_queued_replies_typed_and_retires_rows() {
        let (tx, rx) = mpsc::channel();
        let counter = Arc::new(AtomicUsize::new(2));
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(Msg::Job(Job {
            req: ExecRequest {
                model: "m".into(),
                batch: 2,
                data: vec![0.0; 2],
            },
            enqueued: Stopwatch::start(),
            reply: reply_tx,
            rows: RowsGuard {
                counter: Arc::clone(&counter),
                rows: 2,
            },
        }))
        .unwrap();
        // A queued Load must also get a reply, not a hang.
        let (load_tx, load_rx) = mpsc::channel();
        tx.send(Msg::Load {
            model: "m".into(),
            reply: load_tx,
        })
        .unwrap();
        fail_queued(&rx, "boom");
        let err = reply_rx.recv().unwrap().unwrap_err();
        assert!(err.downcast_ref::<WorkerCrashed>().is_some());
        assert!(load_rx.recv().unwrap().is_err());
        assert_eq!(counter.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn panic_message_extracts_str_and_string() {
        let a: Box<dyn std::any::Any + Send> = Box::new("static msg");
        assert_eq!(panic_message(&a), "static msg");
        let b: Box<dyn std::any::Any + Send> = Box::new(String::from("owned msg"));
        assert_eq!(panic_message(&b), "owned msg");
        let c: Box<dyn std::any::Any + Send> = Box::new(42u32);
        assert_eq!(panic_message(&c), "panic in device worker");
    }
}
