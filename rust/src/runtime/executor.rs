//! The device executor: one thread owning every (model × bucket) backend
//! slot — the Rust incarnation of the paper's `fmodels` shared-memory
//! ensemble (§2.2), now dispatching through the pluggable [`Backend`]
//! trait instead of calling XLA directly.
//!
//! Backend instances (like the xla handles they may wrap) are `!Send`, so
//! all device work happens on this thread; request threads hold a cheap
//! [`ExecutorHandle`] (`Clone + Send + Sync`) and submit [`ExecRequest`]s
//! over a channel. Device work is therefore serialized exactly like N
//! models sharing one GPU stream. The thread also owns a [`BufferArena`]:
//! padded feeds, hidden activations, and output logits all come from
//! recycled storage, so a steady-state flush on the `cpu`/`quant`
//! backends performs zero heap allocations (`tests/alloc_counting.rs`).
//! The XLA client is created lazily — a manifest served entirely by the
//! CPU backends never touches PJRT.

use super::arena::BufferArena;
use super::backend::{
    self, Backend, BackendKind, CpuBackend, CpuWorkers, ModelGraph, QuantBackend, QuantModel,
    XlaBackend,
};
use super::manifest::{split_slot, Manifest, ModelEntry};
use super::tensor::TensorView;
use crate::chaos;
use crate::util::Stopwatch;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

/// Typed marker for a device-worker crash. Travels through `anyhow` so
/// the coordinator can recover it into the `exec.worker_crashed` taxonomy
/// row instead of an untyped 500 — the runtime layer itself stays
/// coordinator-free.
#[derive(Debug, Clone)]
pub struct WorkerCrashed {
    pub detail: String,
}

impl WorkerCrashed {
    pub fn new(detail: impl Into<String>) -> WorkerCrashed {
        WorkerCrashed {
            detail: detail.into(),
        }
    }
}

impl fmt::Display for WorkerCrashed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "device worker crashed: {}", self.detail)
    }
}

impl std::error::Error for WorkerCrashed {}

/// One inference job for a single model.
#[derive(Debug, Clone)]
pub struct ExecRequest {
    pub model: String,
    /// True (unpadded) batch size; must be ≥ 1 and ≤ the model's max bucket.
    pub batch: usize,
    /// Row-major `(batch, H, W, C)` input, already normalized. A shared
    /// view: N models × chunks all reference one request buffer.
    pub data: TensorView,
}

/// Result of one inference job.
#[derive(Debug, Clone)]
pub struct ExecResponse {
    /// Row-major `(batch, num_classes)` logits, truncated to the true
    /// batch. A view into arena-recycled storage: the buffer returns to
    /// the executor's pool when the last reference drops (response
    /// rendered), closing the zero-alloc loop.
    pub logits: TensorView,
    /// Bucket the job actually ran on (≥ batch).
    pub bucket: usize,
    /// Which backend executed (`"xla"`, `"cpu"`, `"quant"`).
    pub backend: &'static str,
    /// Channel handoff: time between submit and the device thread picking
    /// the job up (NOT kernel time — the coordinator reports it as
    /// `stage_submit_us`).
    pub queue_micros: u64,
    /// Device execution time (pad + kernel/literal + readback).
    pub exec_micros: u64,
}

/// Pairs the submit-side `in_flight_rows` increment on EVERY exit path:
/// executed, dropped with a crashed worker's queue, or bounced off a
/// closed channel — the load signal can never leak rows.
struct RowsGuard {
    counter: Arc<AtomicUsize>,
    rows: usize,
}

impl Drop for RowsGuard {
    fn drop(&mut self) {
        self.counter.fetch_sub(self.rows, Ordering::Relaxed);
    }
}

struct Job {
    req: ExecRequest,
    enqueued: Stopwatch,
    reply: mpsc::Sender<Result<ExecResponse>>,
    rows: RowsGuard,
}

/// Channel protocol to the device thread. An explicit `Shutdown` message
/// (rather than relying on channel closure) lets `Executor::drop` stop the
/// thread even while cloned `ExecutorHandle`s still hold senders.
/// `Load`/`Unload` are the runtime model-lifecycle messages behind the
/// `/v1` control plane: compile a model's artifacts into (or evict them
/// from) this device without restarting the server.
enum Msg {
    Job(Job),
    Load {
        model: String,
        reply: mpsc::Sender<Result<bool>>,
    },
    Unload {
        model: String,
        reply: mpsc::Sender<Result<bool>>,
    },
    Shutdown,
}

/// Which artifacts an executor loads (subset support is what lets the
/// benches build "one model per device" baselines) and how it executes
/// them (backend selection, worker sizing, arena cap).
#[derive(Debug, Clone, Default)]
pub struct ExecutorOptions {
    /// Models to load; `None` = every model in the manifest.
    pub models: Option<Vec<String>>,
    /// Buckets to compile; `None` = every bucket in the manifest.
    pub buckets: Option<Vec<usize>>,
    /// Verify artifact SHA-256 against the manifest before loading
    /// (applies to boot-time compilation AND runtime loads).
    pub verify_sha: bool,
    /// Verify artifact SHA-256 only on runtime `load_model` requests —
    /// for callers that already verified everything at startup and don't
    /// want boot-time compilation to hash each artifact again.
    pub verify_on_load: bool,
    /// Run one warmup execution per slot after loading (also pre-warms
    /// the arena shelves, so the first real flush is already zero-alloc).
    pub warmup: bool,
    /// Global backend override (`--backend`); beats per-model config and
    /// the manifest. `None`/`"auto"` defers down the precedence chain.
    pub backend: Option<String>,
    /// Per-model config overrides `(bare model name, backend)`.
    pub backend_overrides: Vec<(String, String)>,
    /// Intra-op CPU lanes; 0 = physical-core heuristic.
    pub cpu_workers: usize,
    /// Arena retention cap in MB; 0 = default (64).
    pub arena_cap_mb: usize,
}

/// Cloneable, thread-safe handle to a device executor.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: mpsc::Sender<Msg>,
    manifest: Arc<Manifest>,
    /// Rows submitted to this device but not yet executed — the load
    /// signal behind the pool's least-loaded dispatch. Incremented at
    /// submit, decremented by the device thread when the job finishes.
    in_flight_rows: Arc<AtomicUsize>,
    /// Cleared by the device thread when it crashes; the pool's dispatch
    /// skips unhealthy executors and its supervisor respawns them.
    healthy: Arc<AtomicBool>,
}

impl ExecutorHandle {
    /// Blocking single-model inference.
    pub fn infer(&self, req: ExecRequest) -> Result<ExecResponse> {
        self.infer_async(req)?
            .recv()
            .map_err(|_| anyhow::Error::new(WorkerCrashed::new("executor dropped the job")))?
    }

    /// Submit without waiting; returns the reply receiver. Lets the
    /// ensemble overlap N model submissions before collecting.
    pub fn infer_async(&self, req: ExecRequest) -> Result<mpsc::Receiver<Result<ExecResponse>>> {
        if let Some(kind) = chaos::decide(chaos::EXEC_SUBMIT) {
            match kind {
                chaos::FaultKind::Panic => panic!("chaos: injected panic at exec.submit"),
                _ => return Err(anyhow!("chaos: injected failure at exec.submit")),
            }
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        // Count the rows BEFORE the send so concurrent least-loaded picks
        // already see this job; the guard travels with the job, so the
        // decrement pairs on every path (executed, crashed, or bounced).
        let rows = req.batch;
        self.in_flight_rows.fetch_add(rows, Ordering::Relaxed);
        let guard = RowsGuard {
            counter: Arc::clone(&self.in_flight_rows),
            rows,
        };
        if self
            .tx
            .send(Msg::Job(Job {
                req,
                enqueued: Stopwatch::start(),
                reply: reply_tx,
                rows: guard,
            }))
            .is_err()
        {
            // The SendError dropped the job (and its guard) for us.
            return Err(anyhow::Error::new(WorkerCrashed::new(
                "executor thread is gone",
            )));
        }
        Ok(reply_rx)
    }

    /// False once the device thread has crashed (until a respawn replaces
    /// this executor).
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::Relaxed)
    }

    /// Rows currently submitted-but-unfinished on this device.
    pub fn in_flight_rows(&self) -> usize {
        self.in_flight_rows.load(Ordering::Relaxed)
    }

    /// Load `model`'s artifacts into this device at runtime (subject to
    /// the executor's bucket filter and SHA verification options).
    /// `Ok(true)` = newly loaded, `Ok(false)` = already fully loaded.
    pub fn load_model(&self, model: &str) -> Result<bool> {
        self.load_model_async(model)?
            .recv()
            .map_err(|_| anyhow!("executor dropped the load request"))?
    }

    /// Submit a runtime load without waiting; returns the reply receiver.
    /// The pool broadcasts loads this way so W workers compile
    /// concurrently (boot-parity) instead of W× sequentially.
    pub fn load_model_async(&self, model: &str) -> Result<mpsc::Receiver<Result<bool>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Load {
                model: model.to_string(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("executor thread is gone"))?;
        Ok(reply_rx)
    }

    /// Evict every slot of `model` from this device, freeing its memory.
    /// `Ok(true)` = something was evicted.
    pub fn unload_model(&self, model: &str) -> Result<bool> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Unload {
                model: model.to_string(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("executor thread is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("executor dropped the unload request"))?
    }

    pub fn manifest(&self) -> &Arc<Manifest> {
        &self.manifest
    }
}

/// Owns the executor thread; dropping shuts it down (after queued work).
pub struct Executor {
    handle: ExecutorHandle,
    thread: Option<thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn the device thread, load all selected slots, and block until
    /// the device is ready (or loading failed).
    pub fn spawn(manifest: Arc<Manifest>, opts: ExecutorOptions) -> Result<Executor> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let m = Arc::clone(&manifest);
        let in_flight_rows = Arc::new(AtomicUsize::new(0));
        let healthy = Arc::new(AtomicBool::new(true));
        let healthy2 = Arc::clone(&healthy);
        let thread = thread::Builder::new()
            .name("flexserve-device".into())
            .spawn(move || device_thread(m, opts, rx, ready_tx, healthy2))
            .context("spawning device executor thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("device thread died during startup"))??;
        Ok(Executor {
            handle: ExecutorHandle {
                tx,
                manifest,
                in_flight_rows,
                healthy,
            },
            thread: Some(thread),
        })
    }

    pub fn handle(&self) -> ExecutorHandle {
        self.handle.clone()
    }

    /// Rows currently submitted-but-unfinished on this device.
    pub fn in_flight_rows(&self) -> usize {
        self.handle.in_flight_rows()
    }

    /// False once the device thread has crashed.
    pub fn is_healthy(&self) -> bool {
        self.handle.is_healthy()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        // Explicit shutdown: cloned handles may still hold senders, so
        // channel closure alone would never arrive.
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Loaded backend slots, nested `model name → bucket → backend`. The
/// inner map is ordered so "smallest loaded bucket that fits" is a range
/// query, and the outer map is queried with a borrowed `&str` — dispatch
/// allocates no `(String, bucket)` key per request.
type BackendMap = HashMap<String, BTreeMap<usize, Box<dyn Backend>>>;

/// Everything the device thread owns: the slot map, the shared-per-model
/// f32 graphs and quantized models backing the CPU paths, the lazy XLA
/// client, and the intra-op worker set.
struct DeviceState {
    /// Created on first XLA slot; CPU-only manifests never touch PJRT.
    client: Option<xla::PjRtClient>,
    slots: BackendMap,
    graphs: HashMap<String, Arc<ModelGraph>>,
    qmodels: HashMap<String, Arc<QuantModel>>,
    workers: Option<Arc<CpuWorkers>>,
}

impl DeviceState {
    fn new() -> DeviceState {
        DeviceState {
            client: None,
            slots: BackendMap::new(),
            graphs: HashMap::new(),
            qmodels: HashMap::new(),
            workers: None,
        }
    }
}

/// Body of the device thread: load everything, then serve jobs forever.
fn device_thread(
    manifest: Arc<Manifest>,
    opts: ExecutorOptions,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<()>>,
    healthy: Arc<AtomicBool>,
) {
    let mut arena = BufferArena::new(opts.arena_cap_mb);
    let mut state = DeviceState::new();
    let setup = (|| -> Result<()> {
        for model in &manifest.models {
            if let Some(want) = &opts.models {
                if !want.contains(&model.name) {
                    continue;
                }
            }
            load_model_slots(&mut state, &manifest, &opts, model, &mut arena)?;
        }
        if state.slots.is_empty() {
            bail!("executor loaded zero slots (model/bucket filter too strict?)");
        }
        Ok(())
    })();

    match setup {
        Ok(()) => {
            let _ = ready.send(Ok(()));
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    }

    // Serve until shutdown (or every handle is dropped).
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Job(job) => {
                let Job {
                    req,
                    enqueued,
                    reply,
                    rows,
                } = job;
                let queue_micros = enqueued.elapsed_micros();
                // Supervised execution: a panic anywhere under execute_job
                // (or an injected chaos panic) must not abandon the reply
                // channel — callers would hang forever on recv().
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    if let Some(kind) = chaos::decide(chaos::EXEC_DEVICE) {
                        match kind {
                            chaos::FaultKind::Panic => {
                                panic!("chaos: injected panic at exec.device")
                            }
                            _ => bail!("chaos: injected failure at exec.device"),
                        }
                    }
                    execute_job(&mut state, &manifest, &mut arena, &req)
                }));
                // Whatever happened, the rows are no longer ahead of anyone.
                drop(rows);
                match outcome {
                    Ok(result) => {
                        let result =
                            result.map(|(logits, bucket, backend, exec_micros)| ExecResponse {
                                logits,
                                bucket,
                                backend,
                                queue_micros,
                                exec_micros,
                            });
                        let _ = reply.send(result); // receiver may have timed out; fine
                    }
                    Err(panic) => {
                        // The worker is poisoned: fail this job and every
                        // queued message with a typed error, flag the
                        // executor unhealthy (dispatch skips it, the pool
                        // supervisor respawns it), and exit the thread.
                        healthy.store(false, Ordering::Relaxed);
                        let detail = panic_message(&panic);
                        let _ = reply.send(Err(WorkerCrashed::new(&detail).into()));
                        fail_queued(&rx, &detail);
                        return;
                    }
                }
            }
            Msg::Load { model, reply } => {
                let result = (|| -> Result<bool> {
                    let entry = manifest
                        .model(&model)
                        .ok_or_else(|| anyhow!("unknown model '{model}'"))?;
                    // Runtime admission re-verifies provenance when either
                    // flag asks for it (startup verification doesn't cover
                    // artifacts that changed on disk since boot).
                    let load_opts = ExecutorOptions {
                        verify_sha: opts.verify_sha || opts.verify_on_load,
                        ..opts.clone()
                    };
                    let added =
                        load_model_slots(&mut state, &manifest, &load_opts, entry, &mut arena)?;
                    // Inner bucket maps are created only on insert, so
                    // presence of the key means ≥ 1 slot.
                    if !state.slots.contains_key(&model) {
                        bail!("bucket filter selects no artifacts for '{model}'");
                    }
                    Ok(added > 0)
                })();
                let _ = reply.send(result);
            }
            Msg::Unload { model, reply } => {
                let had = state.slots.remove(&model).is_some();
                state.graphs.remove(&model);
                state.qmodels.remove(&model);
                let _ = reply.send(Ok(had));
            }
            Msg::Shutdown => break,
        }
    }
}

/// Best-effort panic payload → human detail (panics carry `&str` or
/// `String` in practice; anything else gets a fixed label).
fn panic_message(panic: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic in device worker".to_string()
    }
}

/// Drain everything already queued behind a crashed worker, replying a
/// typed error so no caller blocks on a dead thread. Each dropped Job's
/// RowsGuard retires its in-flight rows.
fn fail_queued(rx: &mpsc::Receiver<Msg>, detail: &str) {
    while let Ok(msg) = rx.try_recv() {
        match msg {
            Msg::Job(job) => {
                let _ = job.reply.send(Err(WorkerCrashed::new(detail).into()));
            }
            Msg::Load { reply, .. } => {
                let _ = reply.send(Err(WorkerCrashed::new(detail).into()));
            }
            Msg::Unload { reply, .. } => {
                let _ = reply.send(Err(WorkerCrashed::new(detail).into()));
            }
            Msg::Shutdown => {}
        }
    }
}

/// Resolve the backend kind for one manifest entry under these options.
fn resolve_kind(opts: &ExecutorOptions, entry: &ModelEntry) -> Result<BackendKind> {
    let (bare, _) = split_slot(&entry.name);
    let per_model = opts
        .backend_overrides
        .iter()
        .find(|(m, _)| m == bare)
        .map(|(_, b)| b.as_str());
    backend::select_kind(
        opts.backend.as_deref(),
        per_model,
        entry.backend.as_deref(),
        &entry.name,
    )
}

/// Load (and optionally warm up) every selected bucket of one model into
/// the slot map, verifying provenance when the options say so.
/// Already-loaded buckets are skipped; returns how many were added.
fn load_model_slots(
    state: &mut DeviceState,
    manifest: &Manifest,
    opts: &ExecutorOptions,
    model: &ModelEntry,
    arena: &mut BufferArena,
) -> Result<usize> {
    let kind = resolve_kind(opts, model)?;
    let mut added = 0;
    for art in &model.buckets {
        if let Some(want) = &opts.buckets {
            if !want.contains(&art.bucket) {
                continue;
            }
        }
        if state
            .slots
            .get(&model.name)
            .is_some_and(|b| b.contains_key(&art.bucket))
        {
            continue;
        }
        let mut be: Box<dyn Backend> = match kind {
            BackendKind::Xla => {
                if opts.verify_sha {
                    manifest
                        .verify_artifact(art)
                        .with_context(|| format!("model {}", model.name))?;
                }
                if state.client.is_none() {
                    state.client =
                        Some(xla::PjRtClient::cpu().context("creating PJRT CPU client")?);
                }
                let client = state.client.as_ref().expect("client just ensured");
                let path = manifest.artifact_path(art);
                // HLO TEXT interchange: see aot.py / DESIGN.md — serialized
                // protos from jax>=0.5 are rejected by xla_extension 0.5.1.
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("parsing HLO text {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling {}", art.file))?;
                Box::new(XlaBackend::new(exe, art.bucket, &manifest.input_shape))
            }
            BackendKind::Cpu => {
                let graph = ensure_graph(state, manifest, opts, model)?;
                let workers = ensure_workers(state, opts);
                Box::new(CpuBackend::new(graph, art.bucket, workers))
            }
            BackendKind::Quant => {
                if !state.qmodels.contains_key(&model.name) {
                    let graph = ensure_graph(state, manifest, opts, model)?;
                    let qm = Arc::new(QuantModel::from_graph(&graph));
                    state.qmodels.insert(model.name.clone(), qm);
                }
                let qm = Arc::clone(state.qmodels.get(&model.name).expect("just ensured"));
                Box::new(QuantBackend::new(qm, art.bucket))
            }
        };
        if opts.warmup {
            let zeros = arena.scratch(art.bucket * manifest.sample_elems());
            be.run(&zeros, arena)
                .with_context(|| format!("warmup {} b{}", model.name, art.bucket))?;
            arena.restore(zeros);
        }
        state
            .slots
            .entry(model.name.clone())
            .or_default()
            .insert(art.bucket, be);
        added += 1;
    }
    Ok(added)
}

/// The per-model f32 graph, loaded once and shared across bucket slots
/// (and with the quantizer).
fn ensure_graph(
    state: &mut DeviceState,
    manifest: &Manifest,
    opts: &ExecutorOptions,
    model: &ModelEntry,
) -> Result<Arc<ModelGraph>> {
    if let Some(g) = state.graphs.get(&model.name) {
        return Ok(Arc::clone(g));
    }
    let g = Arc::new(ModelGraph::load(manifest, model, opts.verify_sha)?);
    state.graphs.insert(model.name.clone(), Arc::clone(&g));
    Ok(g)
}

fn ensure_workers(state: &mut DeviceState, opts: &ExecutorOptions) -> Arc<CpuWorkers> {
    if state.workers.is_none() {
        state.workers = Some(Arc::new(CpuWorkers::new(opts.cpu_workers)));
    }
    Arc::clone(state.workers.as_ref().expect("just set"))
}

fn execute_job(
    state: &mut DeviceState,
    manifest: &Manifest,
    arena: &mut BufferArena,
    req: &ExecRequest,
) -> Result<(TensorView, usize, &'static str, u64)> {
    let elems = manifest.sample_elems();
    if req.batch == 0 {
        bail!("empty batch");
    }
    if req.data.len() != req.batch * elems {
        bail!(
            "payload size {} != batch {} x {} elems",
            req.data.len(),
            req.batch,
            elems
        );
    }
    let model = manifest
        .model(&req.model)
        .ok_or_else(|| anyhow!("unknown model '{}'", req.model))?;
    // Borrowed `&str` lookup: the dispatch loop allocates no key strings.
    let loaded = state
        .slots
        .get_mut(req.model.as_str())
        .ok_or_else(|| anyhow!("model '{}' has no loaded slots (unloaded?)", req.model))?;
    // Smallest *loaded* bucket that fits (the inner map is bucket-ordered).
    let (&bucket, be) = loaded.range_mut(req.batch..).next().ok_or_else(|| {
        anyhow!(
            "batch {} exceeds largest loaded bucket for '{}' (max {})",
            req.batch,
            req.model,
            model.max_bucket()
        )
    })?;

    let sw = Stopwatch::start();
    // Pad into arena scratch (zero-filled tail rows) when the batch does
    // not exactly fill the bucket.
    let mut padded = None;
    let feed: &[f32] = if bucket == req.batch {
        req.data.as_slice()
    } else {
        let mut s = arena.scratch(bucket * elems);
        s[..req.batch * elems].copy_from_slice(req.data.as_slice());
        padded = Some(s);
        padded.as_deref().expect("just set")
    };
    let full = be.run(feed, arena)?;
    if let Some(s) = padded.take() {
        arena.restore(s);
    }
    let exec_micros = sw.elapsed_micros();
    // Zero-copy truncation to the true batch: a sub-view of the same
    // refcounted buffer.
    let logits = full.slice(0, req.batch * manifest.num_classes());
    Ok((logits, bucket, be.kind().as_str(), exec_micros))
}

#[cfg(test)]
mod tests {
    // Device-backed (XLA) executor tests live in rust/tests/ and need
    // `make artifacts`; everything here runs device-free — the CPU-backend
    // paths boot from synthetic artifacts.
    use super::*;
    use crate::runtime::synth;

    #[test]
    fn options_default_loads_everything_on_xla() {
        let o = ExecutorOptions::default();
        assert!(o.models.is_none());
        assert!(o.buckets.is_none());
        assert!(!o.verify_sha);
        assert!(o.backend.is_none());
        assert!(o.backend_overrides.is_empty());
        assert_eq!(o.cpu_workers, 0);
        assert_eq!(o.arena_cap_mb, 0);
    }

    #[test]
    fn worker_crashed_is_typed_through_anyhow() {
        let e: anyhow::Error = WorkerCrashed::new("boom").into();
        assert_eq!(e.downcast_ref::<WorkerCrashed>().unwrap().detail, "boom");
        assert!(e.to_string().contains("device worker crashed: boom"));
    }

    #[test]
    fn fail_queued_replies_typed_and_retires_rows() {
        let (tx, rx) = mpsc::channel();
        let counter = Arc::new(AtomicUsize::new(2));
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(Msg::Job(Job {
            req: ExecRequest {
                model: "m".into(),
                batch: 2,
                data: vec![0.0; 2].into(),
            },
            enqueued: Stopwatch::start(),
            reply: reply_tx,
            rows: RowsGuard {
                counter: Arc::clone(&counter),
                rows: 2,
            },
        }))
        .unwrap();
        // A queued Load must also get a reply, not a hang.
        let (load_tx, load_rx) = mpsc::channel();
        tx.send(Msg::Load {
            model: "m".into(),
            reply: load_tx,
        })
        .unwrap();
        fail_queued(&rx, "boom");
        let err = reply_rx.recv().unwrap().unwrap_err();
        assert!(err.downcast_ref::<WorkerCrashed>().is_some());
        assert!(load_rx.recv().unwrap().is_err());
        assert_eq!(counter.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn panic_message_extracts_str_and_string() {
        let a: Box<dyn std::any::Any + Send> = Box::new("static msg");
        assert_eq!(panic_message(&a), "static msg");
        let b: Box<dyn std::any::Any + Send> = Box::new(String::from("owned msg"));
        assert_eq!(panic_message(&b), "owned msg");
        let c: Box<dyn std::any::Any + Send> = Box::new(42u32);
        assert_eq!(panic_message(&c), "panic in device worker");
    }

    fn synth_manifest() -> Arc<Manifest> {
        Arc::new(Manifest::load(synth::ensure_synthetic()).unwrap())
    }

    #[test]
    fn cpu_backend_serves_every_bucket_device_free() {
        let manifest = synth_manifest();
        let exec = Executor::spawn(
            Arc::clone(&manifest),
            ExecutorOptions {
                verify_sha: true,
                warmup: true,
                ..Default::default()
            },
        )
        .unwrap();
        let h = exec.handle();
        for batch in [1usize, 3, 17, 32] {
            let resp = h
                .infer(ExecRequest {
                    model: "mlp".into(),
                    batch,
                    data: vec![0.25; batch * 256].into(),
                })
                .unwrap();
            assert_eq!(resp.logits.len(), batch * 4, "batch {batch}");
            assert_eq!(resp.backend, "cpu");
            assert!(resp.bucket >= batch);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn quant_override_serves_and_reports_backend() {
        let manifest = synth_manifest();
        let exec = Executor::spawn(
            Arc::clone(&manifest),
            ExecutorOptions {
                backend: Some("quant".into()),
                models: Some(vec!["cnn_s".into()]),
                ..Default::default()
            },
        )
        .unwrap();
        let resp = exec
            .handle()
            .infer(ExecRequest {
                model: "cnn_s".into(),
                batch: 2,
                data: vec![0.5; 2 * 256].into(),
            })
            .unwrap();
        assert_eq!(resp.backend, "quant");
        assert_eq!(resp.logits.len(), 2 * 4);
    }

    #[test]
    fn load_unload_cycle_on_cpu_backend() {
        let manifest = synth_manifest();
        let exec = Executor::spawn(
            Arc::clone(&manifest),
            ExecutorOptions {
                models: Some(vec!["mlp".into()]),
                ..Default::default()
            },
        )
        .unwrap();
        let h = exec.handle();
        // cnn_s not loaded yet → inference fails, load succeeds, then works.
        assert!(h
            .infer(ExecRequest {
                model: "cnn_s".into(),
                batch: 1,
                data: vec![0.0; 256].into(),
            })
            .is_err());
        assert!(h.load_model("cnn_s").unwrap());
        assert!(!h.load_model("cnn_s").unwrap(), "second load is a no-op");
        assert!(h
            .infer(ExecRequest {
                model: "cnn_s".into(),
                batch: 1,
                data: vec![0.0; 256].into(),
            })
            .is_ok());
        assert!(h.unload_model("cnn_s").unwrap());
        assert!(!h.unload_model("cnn_s").unwrap());
    }

    #[test]
    fn backend_without_grammar_is_typed_unsupported() {
        // A legacy HLO-only manifest forced onto the cpu backend must
        // surface the typed BackendUnsupported (→ 409 at the coordinator).
        let v = crate::json::parse(
            r#"{"format_version":1,"input_shape":[4],"classes":["a","b"],
                "normalize":{"mean":0,"std":1},"buckets":[1],
                "models":{"legacy":{"param_count":1,"test_acc":0.9,
                  "params_sha256":"s",
                  "buckets":{"1":{"file":"legacy.hlo.txt","sha256":"s","bytes":1}}}}}"#,
        )
        .unwrap();
        let manifest =
            Arc::new(Manifest::from_value(std::path::PathBuf::from("/tmp"), &v).unwrap());
        let err = Executor::spawn(
            manifest,
            ExecutorOptions {
                backend: Some("cpu".into()),
                ..Default::default()
            },
        )
        .unwrap_err();
        let u = err
            .downcast_ref::<backend::BackendUnsupported>()
            .expect("expected typed BackendUnsupported");
        assert_eq!(u.model, "legacy");
        assert_eq!(u.backend, "cpu");
    }

    #[test]
    fn unknown_backend_name_is_typed_unsupported() {
        let manifest = synth_manifest();
        let err = Executor::spawn(
            manifest,
            ExecutorOptions {
                backend: Some("tpu".into()),
                ..Default::default()
            },
        )
        .unwrap_err();
        assert!(err.downcast_ref::<backend::BackendUnsupported>().is_some());
    }
}
