//! L3 runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on XLA PJRT — the only place the `xla` crate is
//! touched.
//!
//! Key design point: the xla handle types (`PjRtClient`,
//! `PjRtLoadedExecutable`, `Literal`) wrap raw pointers and are `!Send`, so
//! they cannot be shared across request threads. Instead a **device
//! executor thread** owns one `PjRtClient` plus all compiled executables,
//! and request threads talk to it over an mpsc channel
//! ([`executor::ExecutorHandle`] is `Clone + Send + Sync`). This is also the
//! faithful model of the paper's §2.2: one shared device, all N ensemble
//! models resident in its memory, forward calls serialized on the device
//! queue. Horizontal scaling (§2.2 "Gunicorn workers") is
//! [`pool::ExecutorPool`]: W executor threads, each owning a full client.

pub mod executor;
pub mod manifest;
pub mod pool;
pub mod supervise;
pub mod tensor;

pub use executor::{ExecRequest, ExecResponse, Executor, ExecutorHandle, WorkerCrashed};
pub use manifest::{slot_name, split_slot, ArtifactRef, Manifest, ModelEntry};
pub use pool::{ExecutorPool, PoolEvent};
pub use supervise::{run_supervisor, Backoff, SupervisorOptions};
pub use tensor::{DType, TensorView};
