//! L3 runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (or the synthetic CPU-backend set from [`synth`]) and executes them
//! through pluggable [`backend`] implementations — XLA PJRT, the blocked
//! SIMD CPU path, or quantized u8 — behind one [`backend::Backend`] trait.
//!
//! Key design point: backend instances (like the xla handle types
//! `PjRtClient`, `PjRtLoadedExecutable`, `Literal`, which wrap raw
//! pointers and are `!Send`) cannot be shared across request threads.
//! Instead a **device executor thread** owns every backend slot plus the
//! [`arena::BufferArena`] their outputs are carved from, and request
//! threads talk to it over an mpsc channel ([`executor::ExecutorHandle`]
//! is `Clone + Send + Sync`). This is also the faithful model of the
//! paper's §2.2: one shared device, all N ensemble models resident in its
//! memory, forward calls serialized on the device queue. Horizontal
//! scaling (§2.2 "Gunicorn workers") is [`pool::ExecutorPool`]: W
//! executor threads, each owning a full device.

pub mod arena;
pub mod backend;
pub mod executor;
pub mod manifest;
pub mod pool;
pub mod supervise;
pub mod synth;
pub mod tensor;

pub use arena::BufferArena;
pub use backend::{Backend, BackendKind, BackendUnsupported, ModelGraph};
pub use executor::{
    ExecRequest, ExecResponse, Executor, ExecutorHandle, ExecutorOptions, WorkerCrashed,
};
pub use manifest::{
    slot_name, split_slot, ArtifactRef, LayerRef, Manifest, ModelEntry, WeightsRef,
};
pub use pool::{ExecutorPool, PoolEvent};
pub use supervise::{run_supervisor, Backoff, SupervisorOptions};
pub use tensor::{DType, TensorView};
