//! Batch padding/truncation for bucketed executables (§2.3 flexible batch
//! sizes under shape-specialized XLA AOT), and the zero-copy payload
//! carrier ([`TensorView`]) the whole data plane hands around.

use std::sync::Arc;

/// A shared, reference-counted view into a row-major f32 batch.
///
/// This is the zero-copy carrier of the predict hot path: the HTTP layer
/// parses the request tensor once, wraps it, and every downstream consumer
/// — the batcher, `Ensemble::forward`'s per-(model, chunk) fan-out, the
/// device executors — holds a `TensorView` into the *same* buffer. Cloning
/// and [`TensorView::slice`] are refcount bumps, never float copies.
#[derive(Debug, Clone)]
pub struct TensorView {
    buf: Arc<[f32]>,
    /// Float offset of this view's first element within `buf`.
    offset: usize,
    /// Float length of this view.
    len: usize,
}

impl TensorView {
    /// Sub-view of `len` floats starting `offset` floats into this view.
    /// Shares the underlying buffer (no copy).
    pub fn slice(&self, offset: usize, len: usize) -> TensorView {
        assert!(
            offset + len <= self.len,
            "slice [{offset}, {}) out of view of {} floats",
            offset + len,
            self.len
        );
        TensorView {
            buf: Arc::clone(&self.buf),
            offset: self.offset + offset,
            len,
        }
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.buf[self.offset..self.offset + self.len]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::ops::Deref for TensorView {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl From<Vec<f32>> for TensorView {
    /// The one conversion at the parse boundary; everything after it is
    /// refcounted sharing.
    fn from(v: Vec<f32>) -> TensorView {
        let len = v.len();
        TensorView {
            buf: v.into(),
            offset: 0,
            len,
        }
    }
}

impl From<Arc<[f32]>> for TensorView {
    fn from(buf: Arc<[f32]>) -> TensorView {
        let len = buf.len();
        TensorView { buf, offset: 0, len }
    }
}

/// Copying conversions for offline tools (benches, tests) that hold plain
/// slices; the serving path never goes through these.
impl From<&[f32]> for TensorView {
    fn from(v: &[f32]) -> TensorView {
        TensorView::from(v.to_vec())
    }
}

impl From<&Vec<f32>> for TensorView {
    fn from(v: &Vec<f32>) -> TensorView {
        TensorView::from(v.clone())
    }
}

/// Pad a row-major `(batch, elems)` tensor up to `bucket` rows with zeros.
/// Returns the input unchanged when `batch == bucket`.
pub fn pad_batch(data: &[f32], batch: usize, bucket: usize, elems: usize) -> Vec<f32> {
    debug_assert_eq!(data.len(), batch * elems, "data len mismatch");
    debug_assert!(bucket >= batch, "bucket must fit batch");
    let mut out = Vec::with_capacity(bucket * elems);
    out.extend_from_slice(data);
    out.resize(bucket * elems, 0.0);
    out
}

/// Truncate bucket-sized output rows back down to the true batch.
pub fn truncate_batch(mut data: Vec<f32>, batch: usize, elems: usize) -> Vec<f32> {
    data.truncate(batch * elems);
    data
}

/// Row-major argmax per row; returns (index, value) pairs.
pub fn argmax_rows(data: &[f32], elems: usize) -> Vec<(usize, f32)> {
    debug_assert!(elems > 0);
    data.chunks_exact(elems)
        .map(|row| {
            let mut best = 0;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            (best, row[best])
        })
        .collect()
}

/// Numerically-stable softmax per row, in place.
pub fn softmax_rows(data: &mut [f32], elems: usize) {
    for row in data.chunks_exact_mut(elems) {
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_view_shares_without_copying() {
        let view = TensorView::from(vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let a = view.slice(0, 2);
        let b = view.slice(2, 4);
        assert_eq!(a.as_slice(), &[1.0, 2.0]);
        assert_eq!(b.as_slice(), &[3.0, 4.0, 5.0, 6.0]);
        // Sub-slicing a sub-view stays anchored to the shared buffer.
        assert_eq!(b.slice(1, 2).as_slice(), &[4.0, 5.0]);
        // Same backing allocation for every view.
        assert_eq!(view.as_slice().as_ptr(), a.as_slice().as_ptr());
        assert_eq!(unsafe { view.as_slice().as_ptr().add(2) }, b.as_slice().as_ptr());
        assert_eq!(view.len(), 6);
        assert!(!view.is_empty());
    }

    #[test]
    #[should_panic(expected = "out of view")]
    fn tensor_view_slice_bounds_checked() {
        TensorView::from(vec![0.0f32; 4]).slice(2, 3);
    }

    #[test]
    fn pad_and_truncate_roundtrip() {
        let data = vec![1.0, 2.0, 3.0, 4.0]; // batch=2, elems=2
        let padded = pad_batch(&data, 2, 4, 2);
        assert_eq!(padded.len(), 8);
        assert_eq!(&padded[..4], &data[..]);
        assert_eq!(&padded[4..], &[0.0; 4]);
        assert_eq!(truncate_batch(padded, 2, 2), data);
    }

    #[test]
    fn pad_noop_when_exact() {
        let data = vec![1.0, 2.0];
        assert_eq!(pad_batch(&data, 1, 1, 2), data);
    }

    #[test]
    fn argmax() {
        let out = argmax_rows(&[0.1, 0.9, -1.0, 5.0, 4.0, 3.0], 3);
        assert_eq!(out[0].0, 1);
        assert_eq!(out[1].0, 0);
        assert!((out[1].1 - 5.0).abs() < 1e-6);
    }

    #[test]
    fn argmax_ties_take_first() {
        let out = argmax_rows(&[1.0, 1.0, 1.0], 3);
        assert_eq!(out[0].0, 0);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut data = vec![1.0, 2.0, 3.0, 1000.0, 1001.0, 999.0];
        softmax_rows(&mut data, 3);
        for row in data.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|v| v.is_finite()));
        }
        // Order preserved.
        assert!(data[2] > data[1] && data[1] > data[0]);
    }
}
