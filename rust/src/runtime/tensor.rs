//! Batch padding/truncation for bucketed executables (§2.3 flexible batch
//! sizes under shape-specialized XLA AOT), the element-type vocabulary
//! shared with the protocol codecs ([`DType`]), and the zero-copy payload
//! carrier ([`TensorView`]) the whole data plane hands around.

use std::sync::Arc;

/// Element types the serving stack speaks on the wire. Device storage is
/// f32-only today: non-f32 inputs are converted at the protocol boundary
/// (the `/v2` codec), so everything past the extractors carries
/// [`DType::F32`]. The enum exists so the wire layers, the inference IR
/// and the tensor carrier agree on one vocabulary — including the names
/// the Open Inference Protocol uses (`FP32`, `INT64`, `UINT8`, `BYTES`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I64,
    U8,
    /// Variable-length byte/string elements (v2 `BYTES`); used for class
    /// name *outputs* only — models take numeric inputs.
    Bytes,
}

impl DType {
    /// Parse an Open-Inference-Protocol datatype name.
    pub fn from_v2(name: &str) -> Option<DType> {
        match name {
            "FP32" => Some(DType::F32),
            "INT64" => Some(DType::I64),
            "UINT8" => Some(DType::U8),
            "BYTES" => Some(DType::Bytes),
            _ => None,
        }
    }

    /// The Open-Inference-Protocol name of this dtype.
    pub fn v2_name(self) -> &'static str {
        match self {
            DType::F32 => "FP32",
            DType::I64 => "INT64",
            DType::U8 => "UINT8",
            DType::Bytes => "BYTES",
        }
    }

    /// Bytes per element (`None` for variable-length [`DType::Bytes`]).
    pub fn size_bytes(self) -> Option<usize> {
        match self {
            DType::F32 => Some(4),
            DType::I64 => Some(8),
            DType::U8 => Some(1),
            DType::Bytes => None,
        }
    }
}

/// A shared, reference-counted view into a row-major f32 batch.
///
/// This is the zero-copy carrier of the predict hot path: the HTTP layer
/// parses the request tensor once, wraps it, and every downstream consumer
/// — the scheduler, `Ensemble::forward`'s per-(model, chunk) fan-out, the
/// device executors — holds a `TensorView` into the *same* buffer. Cloning
/// and [`TensorView::slice`] are refcount bumps, never float copies.
///
/// A view also carries its element type and (optionally) its logical
/// shape, so typed, shaped protocol tensors flow through
/// `ExecRequest`/`Ensemble::forward`/the scheduler unchanged. Storage is
/// f32 today — non-f32 wire inputs are converted at the protocol boundary
/// — so `dtype` is [`DType::F32`] everywhere past the extractors.
#[derive(Debug, Clone)]
pub struct TensorView {
    buf: Arc<[f32]>,
    /// Float offset of this view's first element within `buf`.
    offset: usize,
    /// Float length of this view.
    len: usize,
    /// Element type of the stored data (post-conversion).
    dtype: DType,
    /// Logical shape, when the producer declared one (`None` = flat).
    /// Shared, so cloning a shaped view stays allocation-free.
    shape: Option<Arc<[usize]>>,
}

impl TensorView {
    /// Sub-view of `len` floats starting `offset` floats into this view.
    /// Shares the underlying buffer (no copy). The sub-view keeps the
    /// dtype but drops the logical shape (a row range of a shaped batch
    /// has a different leading dimension).
    pub fn slice(&self, offset: usize, len: usize) -> TensorView {
        assert!(
            offset + len <= self.len,
            "slice [{offset}, {}) out of view of {} floats",
            offset + len,
            self.len
        );
        TensorView {
            buf: Arc::clone(&self.buf),
            offset: self.offset + offset,
            len,
            dtype: self.dtype,
            shape: None,
        }
    }

    /// Attach a logical shape (e.g. `[batch, H, W, C]`); the product must
    /// match the view's length.
    pub fn with_shape(mut self, shape: &[usize]) -> TensorView {
        debug_assert_eq!(
            shape.iter().product::<usize>(),
            self.len,
            "shape {shape:?} does not cover {} floats",
            self.len
        );
        self.shape = Some(shape.into());
        self
    }

    /// Element type of the stored data.
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Logical shape, if the producer declared one (empty slice = flat).
    pub fn shape(&self) -> &[usize] {
        self.shape.as_deref().unwrap_or(&[])
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.buf[self.offset..self.offset + self.len]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::ops::Deref for TensorView {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

/// Content equality (the viewed floats), not buffer identity — two views
/// into different allocations with the same values compare equal.
impl PartialEq for TensorView {
    fn eq(&self, other: &TensorView) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Vec<f32>> for TensorView {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[f32]> for TensorView {
    fn eq(&self, other: &[f32]) -> bool {
        self.as_slice() == other
    }
}

impl From<Vec<f32>> for TensorView {
    /// The one conversion at the parse boundary; everything after it is
    /// refcounted sharing.
    fn from(v: Vec<f32>) -> TensorView {
        let len = v.len();
        TensorView {
            buf: v.into(),
            offset: 0,
            len,
            dtype: DType::F32,
            shape: None,
        }
    }
}

impl From<Arc<[f32]>> for TensorView {
    fn from(buf: Arc<[f32]>) -> TensorView {
        let len = buf.len();
        TensorView {
            buf,
            offset: 0,
            len,
            dtype: DType::F32,
            shape: None,
        }
    }
}

/// Copying conversions for offline tools (benches, tests) that hold plain
/// slices; the serving path never goes through these.
impl From<&[f32]> for TensorView {
    fn from(v: &[f32]) -> TensorView {
        TensorView::from(v.to_vec())
    }
}

impl From<&Vec<f32>> for TensorView {
    fn from(v: &Vec<f32>) -> TensorView {
        TensorView::from(v.clone())
    }
}

/// Pad a row-major `(batch, elems)` tensor up to `bucket` rows with zeros.
/// Returns the input unchanged when `batch == bucket`.
pub fn pad_batch(data: &[f32], batch: usize, bucket: usize, elems: usize) -> Vec<f32> {
    debug_assert_eq!(data.len(), batch * elems, "data len mismatch");
    debug_assert!(bucket >= batch, "bucket must fit batch");
    let mut out = Vec::with_capacity(bucket * elems);
    out.extend_from_slice(data);
    out.resize(bucket * elems, 0.0);
    out
}

/// Truncate bucket-sized output rows back down to the true batch.
pub fn truncate_batch(mut data: Vec<f32>, batch: usize, elems: usize) -> Vec<f32> {
    data.truncate(batch * elems);
    data
}

/// Row-major argmax per row; returns (index, value) pairs.
pub fn argmax_rows(data: &[f32], elems: usize) -> Vec<(usize, f32)> {
    debug_assert!(elems > 0);
    data.chunks_exact(elems)
        .map(|row| {
            let mut best = 0;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            (best, row[best])
        })
        .collect()
}

/// Numerically-stable softmax per row, in place.
pub fn softmax_rows(data: &mut [f32], elems: usize) {
    for row in data.chunks_exact_mut(elems) {
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_view_shares_without_copying() {
        let view = TensorView::from(vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let a = view.slice(0, 2);
        let b = view.slice(2, 4);
        assert_eq!(a.as_slice(), &[1.0, 2.0]);
        assert_eq!(b.as_slice(), &[3.0, 4.0, 5.0, 6.0]);
        // Sub-slicing a sub-view stays anchored to the shared buffer.
        assert_eq!(b.slice(1, 2).as_slice(), &[4.0, 5.0]);
        // Same backing allocation for every view.
        assert_eq!(view.as_slice().as_ptr(), a.as_slice().as_ptr());
        assert_eq!(unsafe { view.as_slice().as_ptr().add(2) }, b.as_slice().as_ptr());
        assert_eq!(view.len(), 6);
        assert!(!view.is_empty());
    }

    #[test]
    #[should_panic(expected = "out of view")]
    fn tensor_view_slice_bounds_checked() {
        TensorView::from(vec![0.0f32; 4]).slice(2, 3);
    }

    #[test]
    fn tensor_view_carries_dtype_and_shape() {
        let view = TensorView::from(vec![0.0f32; 8]).with_shape(&[2, 2, 2, 1]);
        assert_eq!(view.dtype(), DType::F32);
        assert_eq!(view.shape(), &[2, 2, 2, 1]);
        // Cloning shares the shape; slicing keeps dtype but goes flat.
        assert_eq!(view.clone().shape(), &[2, 2, 2, 1]);
        let sub = view.slice(4, 4);
        assert_eq!(sub.dtype(), DType::F32);
        assert!(sub.shape().is_empty());
        // Flat views report an empty shape.
        assert!(TensorView::from(vec![1.0f32]).shape().is_empty());
    }

    #[test]
    fn dtype_v2_names_roundtrip() {
        for dt in [DType::F32, DType::I64, DType::U8, DType::Bytes] {
            assert_eq!(DType::from_v2(dt.v2_name()), Some(dt));
        }
        assert_eq!(DType::from_v2("FP64"), None);
        assert_eq!(DType::from_v2("fp32"), None); // v2 names are uppercase
        assert_eq!(DType::F32.size_bytes(), Some(4));
        assert_eq!(DType::I64.size_bytes(), Some(8));
        assert_eq!(DType::U8.size_bytes(), Some(1));
        assert_eq!(DType::Bytes.size_bytes(), None);
    }

    #[test]
    fn pad_and_truncate_roundtrip() {
        let data = vec![1.0, 2.0, 3.0, 4.0]; // batch=2, elems=2
        let padded = pad_batch(&data, 2, 4, 2);
        assert_eq!(padded.len(), 8);
        assert_eq!(&padded[..4], &data[..]);
        assert_eq!(&padded[4..], &[0.0; 4]);
        assert_eq!(truncate_batch(padded, 2, 2), data);
    }

    #[test]
    fn pad_noop_when_exact() {
        let data = vec![1.0, 2.0];
        assert_eq!(pad_batch(&data, 1, 1, 2), data);
    }

    #[test]
    fn argmax() {
        let out = argmax_rows(&[0.1, 0.9, -1.0, 5.0, 4.0, 3.0], 3);
        assert_eq!(out[0].0, 1);
        assert_eq!(out[1].0, 0);
        assert!((out[1].1 - 5.0).abs() < 1e-6);
    }

    #[test]
    fn argmax_ties_take_first() {
        let out = argmax_rows(&[1.0, 1.0, 1.0], 3);
        assert_eq!(out[0].0, 0);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut data = vec![1.0, 2.0, 3.0, 1000.0, 1001.0, 999.0];
        softmax_rows(&mut data, 3);
        for row in data.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|v| v.is_finite()));
        }
        // Order preserved.
        assert!(data[2] > data[1] && data[1] > data[0]);
    }
}
