//! Size-bucketed buffer arena for the device thread.
//!
//! Every flush used to allocate: a padded input literal, the device output
//! vector, and the truncated logits vector handed to the coordinator. The
//! arena replaces all of that with recycled storage so a steady-state flush
//! performs **zero heap allocations** (pinned by `tests/alloc_counting.rs`).
//!
//! Two kinds of storage live here:
//!
//! - **Shared output buffers** (`Arc<[f32]>`): handed out as [`TensorView`]s
//!   that travel through the scheduler, the ensemble, and response rendering.
//!   The arena keeps one clone of each `Arc` on a shelf keyed by length;
//!   a buffer is reusable exactly when its strong count drops back to 1,
//!   i.e. when the response that borrowed it has been rendered and dropped.
//!   No free-list bookkeeping, no cross-thread signalling — the `Arc`
//!   refcount *is* the occupancy bit.
//! - **Scratch vectors** (`Vec<f32>`): private intermediates (padded feeds,
//!   hidden-layer activations) checked out with [`BufferArena::scratch`] and
//!   returned with [`BufferArena::restore`].
//!
//! The arena is owned by a single executor device thread and is deliberately
//! a plain `&mut self` struct: no atomics, no locks beyond the refcounts
//! `Arc` already carries.

use std::collections::HashMap;
use std::sync::Arc;

use super::tensor::TensorView;

/// Default retention cap when the config leaves `arena_cap_mb` at 0.
pub const DEFAULT_CAP_MB: usize = 64;

#[derive(Debug)]
pub struct BufferArena {
    /// Shared output buffers keyed by length in floats. Entries whose
    /// strong count is 1 are free; others are still referenced by in-flight
    /// responses.
    shelves: HashMap<usize, Vec<Arc<[f32]>>>,
    /// Returned scratch vectors, reused by any request whose length fits
    /// the retained capacity.
    scratch: Vec<Vec<f32>>,
    cap_bytes: usize,
    /// Bytes currently retained across shelves + scratch free list.
    retained_bytes: usize,
    hits: u64,
    misses: u64,
}

impl BufferArena {
    /// `cap_mb = 0` selects [`DEFAULT_CAP_MB`].
    pub fn new(cap_mb: usize) -> BufferArena {
        let cap = if cap_mb == 0 { DEFAULT_CAP_MB } else { cap_mb };
        BufferArena {
            shelves: HashMap::new(),
            scratch: Vec::new(),
            cap_bytes: cap * 1024 * 1024,
            retained_bytes: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Check out a shared buffer of exactly `len` floats, let `fill` write
    /// it, and return it as a [`TensorView`]. The arena retains a clone so
    /// the storage is recycled once every outside reference is dropped.
    pub fn with_output<F>(&mut self, len: usize, fill: F) -> TensorView
    where
        F: FnOnce(&mut [f32]),
    {
        let shelf = self.shelves.entry(len).or_default();
        for arc in shelf.iter_mut() {
            if Arc::strong_count(arc) == 1 {
                self.hits += 1;
                // Sole owner → get_mut cannot fail.
                fill(Arc::get_mut(arc).expect("strong_count==1"));
                return TensorView::from(arc.clone());
            }
        }
        self.misses += 1;
        let mut arc: Arc<[f32]> = vec![0.0f32; len].into();
        fill(Arc::get_mut(&mut arc).expect("fresh arc"));
        let view = TensorView::from(arc.clone());
        let bytes = len * std::mem::size_of::<f32>();
        if self.retained_bytes + bytes <= self.cap_bytes {
            self.retained_bytes += bytes;
            shelf.push(arc);
        }
        view
    }

    /// Check out a zero-filled scratch vector with `len` elements.
    /// Return it with [`restore`] so the
    /// capacity is reused; after warm-up, a `scratch`/`restore` pair whose
    /// length was seen before allocates nothing.
    ///
    /// [`restore`]: BufferArena::restore
    pub fn scratch(&mut self, len: usize) -> Vec<f32> {
        let pos = self.scratch.iter().position(|v| v.capacity() >= len);
        let mut v = match pos {
            Some(i) => {
                self.hits += 1;
                let v = self.scratch.swap_remove(i);
                self.retained_bytes -=
                    v.capacity() * std::mem::size_of::<f32>();
                v
            }
            None => {
                self.misses += 1;
                Vec::with_capacity(len)
            }
        };
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Return a scratch vector to the free list (subject to the byte cap).
    pub fn restore(&mut self, v: Vec<f32>) {
        let bytes = v.capacity() * std::mem::size_of::<f32>();
        if bytes > 0 && self.retained_bytes + bytes <= self.cap_bytes {
            self.retained_bytes += bytes;
            self.scratch.push(v);
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn retained_bytes(&self) -> usize {
        self.retained_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_recycles_when_refs_drop() {
        let mut a = BufferArena::new(1);
        let v1 = a.with_output(8, |b| b.fill(1.0));
        assert_eq!(a.misses(), 1);
        // Still referenced → second checkout must allocate a new buffer.
        let v2 = a.with_output(8, |b| b.fill(2.0));
        assert_eq!(a.misses(), 2);
        assert_eq!(&v1[..2], &[1.0, 1.0]);
        assert_eq!(&v2[..2], &[2.0, 2.0]);
        drop(v1);
        drop(v2);
        // Both released → next checkout is a hit.
        let v3 = a.with_output(8, |b| b.fill(3.0));
        assert_eq!(a.hits(), 1);
        assert_eq!(&v3[..2], &[3.0, 3.0]);
    }

    #[test]
    fn output_does_not_clobber_live_views() {
        let mut a = BufferArena::new(1);
        let v1 = a.with_output(4, |b| b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]));
        let v2 = a.with_output(4, |b| b.fill(9.0));
        assert_eq!(v1.as_slice(), &[1.0, 2.0, 3.0, 4.0], "live view untouched");
        drop(v2);
    }

    #[test]
    fn scratch_reuses_capacity() {
        let mut a = BufferArena::new(1);
        let s = a.scratch(100);
        assert_eq!(s.len(), 100);
        let cap = s.capacity();
        a.restore(s);
        let s2 = a.scratch(64);
        assert_eq!(s2.len(), 64);
        assert_eq!(s2.capacity(), cap, "smaller request reuses the vector");
        assert_eq!(a.hits(), 1);
    }

    #[test]
    fn scratch_contents_are_zeroed() {
        let mut a = BufferArena::new(1);
        let mut s = a.scratch(4);
        s.fill(7.0);
        a.restore(s);
        let s2 = a.scratch(4);
        assert_eq!(s2, vec![0.0; 4]);
    }

    #[test]
    fn cap_bounds_retention() {
        // 1 MB cap = 262144 floats; a 300k-float scratch is never retained.
        let mut a = BufferArena::new(1);
        let s = a.scratch(300_000);
        a.restore(s);
        assert_eq!(a.retained_bytes(), 0);
        let _ = a.scratch(300_000);
        assert_eq!(a.misses(), 2, "oversized scratch always allocates");
    }

    #[test]
    fn distinct_lengths_get_distinct_shelves() {
        let mut a = BufferArena::new(1);
        let v1 = a.with_output(4, |b| b.fill(1.0));
        drop(v1);
        let v2 = a.with_output(8, |b| b.fill(2.0));
        assert_eq!(a.misses(), 2);
        drop(v2);
        let v3 = a.with_output(4, |b| b.fill(3.0));
        assert_eq!(a.hits(), 1);
        assert_eq!(v3.len(), 4);
    }
}
