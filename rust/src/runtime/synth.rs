//! Synthetic CPU-backend artifacts: a deterministic, device-free stand-in
//! for `make artifacts`.
//!
//! The real pipeline (python/compile/aot.py) trains the zoo and lowers it
//! to HLO text that only the XLA backend can serve. This module writes a
//! manifest whose three models (`cnn_s`, `cnn_m`, `mlp` — same names,
//! shapes, classes, and buckets) carry the linear/MLP **layer grammar** and
//! an f32 weights sidecar instead, with `"backend": "cpu"`, so the full
//! serve stack boots with no XLA artifacts at all. The integration suites
//! that used to self-skip without `make artifacts` now fall back to this —
//! an always-on CI path — and `backend-smoke` / `bench --backend-stack`
//! boot from it directly.
//!
//! Generation is seeded and byte-deterministic: same seed → same sidecar
//! bytes → same sha256 in the manifest, so provenance verification is as
//! real as it is for trained weights.

use crate::json::{self, Value};
use crate::util::Prng;
use crate::workload;
use anyhow::{Context, Result};
use sha2::{Digest, Sha256};
use std::path::{Path, PathBuf};

/// Bump when the generated layout changes — the cached temp dir is keyed
/// by this.
const LAYOUT: &str = "flexserve-synth-v1";

const SEED: u64 = 0xF1E2_5E44;

/// Same bucket ladder as aot.py.
const BUCKETS: [usize; 6] = [1, 2, 4, 8, 16, 32];

/// (name, layer widths, test_acc). 256 = 16×16×1 input, 4 classes. The
/// cnn models are dense stand-ins with comparable parameter budgets —
/// the layer grammar has no conv op, and for backend plumbing none is
/// needed.
const ZOO: [(&str, &[usize], f64); 3] = [
    ("cnn_s", &[256, 32, 4], 0.88),
    ("cnn_m", &[256, 64, 64, 4], 0.92),
    ("mlp", &[256, 128, 64, 4], 0.90),
];

/// Real artifacts if `make artifacts` produced them, else the shared
/// synthetic set. This is what the integration suites boot from.
pub fn ensure_artifacts() -> PathBuf {
    if let Some(dir) = std::env::var_os("FLEXSERVE_ARTIFACTS").map(PathBuf::from) {
        if dir.join("manifest.json").exists() {
            return dir;
        }
    }
    let real = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if real.join("manifest.json").exists() {
        return real;
    }
    ensure_synthetic()
}

/// The cached synthetic artifact dir, generating it on first use.
/// Concurrency-safe across processes: generation goes to a pid-suffixed
/// staging dir that is renamed into place (first writer wins).
pub fn ensure_synthetic() -> PathBuf {
    let dir = std::env::temp_dir().join(LAYOUT);
    if dir.join("manifest.json").exists() {
        return dir;
    }
    let staging = std::env::temp_dir().join(format!("{LAYOUT}.{}", std::process::id()));
    write_synthetic(&staging, SEED).expect("writing synthetic artifacts");
    if std::fs::rename(&staging, &dir).is_err() {
        // Lost the race (or a partial dir exists): if a usable manifest is
        // there, defer to it; otherwise fill the dir file-by-file.
        if !dir.join("manifest.json").exists() {
            let _ = std::fs::create_dir_all(&dir);
            if let Ok(entries) = std::fs::read_dir(&staging) {
                for e in entries.flatten() {
                    let _ = std::fs::rename(e.path(), dir.join(e.file_name()));
                }
            }
        }
        let _ = std::fs::remove_dir_all(&staging);
    }
    dir
}

/// Write a complete synthetic artifact set (manifest + weights sidecars)
/// into `dir`. Deterministic in `seed`.
pub fn write_synthetic(dir: &Path, seed: u64) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    let mut models = Vec::new();
    for (name, widths, test_acc) in ZOO {
        // Per-model child stream so adding a model never shifts another's
        // weights.
        let mut prng = Prng::new(seed ^ hash_name(name));
        let mut weights = Vec::new();
        let mut layers = Vec::new();
        for (i, w) in widths.windows(2).enumerate() {
            let (fan_in, fan_out) = (w[0], w[1]);
            let w_off = weights.len();
            for _ in 0..fan_in * fan_out {
                weights.push((prng.normal() as f32) / (fan_in as f32).sqrt());
            }
            let b_off = weights.len();
            for _ in 0..fan_out {
                weights.push(prng.normal() as f32 * 0.05);
            }
            let act = if i + 2 == widths.len() { "linear" } else { "relu" };
            layers.push(json::obj([
                ("op", Value::from("linear")),
                ("in", Value::from(fan_in)),
                ("out", Value::from(fan_out)),
                ("act", Value::from(act)),
                ("w_off", Value::from(w_off)),
                ("b_off", Value::from(b_off)),
            ]));
        }
        let mut bytes = Vec::with_capacity(weights.len() * 4);
        for v in &weights {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let sha: String = Sha256::digest(&bytes).iter().map(|b| format!("{b:02x}")).collect();
        let file = format!("{name}.weights.f32");
        std::fs::write(dir.join(&file), &bytes)
            .with_context(|| format!("writing {file}"))?;
        // Every bucket references the weights sidecar: the CPU/quant
        // backends are bucket-shape-agnostic, and `verify_artifact` then
        // checks real bytes for every slot exactly like HLO artifacts.
        let buckets = Value::Obj(
            BUCKETS
                .iter()
                .map(|b| {
                    (
                        b.to_string(),
                        json::obj([
                            ("file", Value::from(file.as_str())),
                            ("sha256", Value::from(sha.as_str())),
                            ("bytes", Value::from(bytes.len())),
                        ]),
                    )
                })
                .collect(),
        );
        models.push((
            name.to_string(),
            json::obj([
                ("param_count", Value::from(weights.len())),
                ("test_acc", Value::from(test_acc)),
                ("params_sha256", Value::from(sha.as_str())),
                ("backend", Value::from("cpu")),
                ("layers", Value::Arr(layers)),
                (
                    "weights",
                    json::obj([
                        ("file", Value::from(file.as_str())),
                        ("sha256", Value::from(sha)),
                        ("bytes", Value::from(bytes.len())),
                    ]),
                ),
                ("buckets", buckets),
            ]),
        ));
    }
    let manifest = json::obj([
        ("format_version", Value::from(1u64)),
        ("input_shape", json::arr([16usize, 16, 1].map(Value::from))),
        (
            "classes",
            json::arr(workload::CLASSES.iter().map(|c| Value::from(*c))),
        ),
        (
            "normalize",
            json::obj([("mean", Value::from(0.1307)), ("std", Value::from(0.3081))]),
        ),
        ("buckets", json::arr(BUCKETS.map(Value::from))),
        ("models", Value::Obj(models)),
        (
            "provenance",
            json::obj([
                ("generator", Value::from("synthetic-cpu")),
                ("interchange", Value::from("f32-weights-sidecar")),
                ("seed", Value::from(seed)),
            ]),
        ),
    ]);
    std::fs::write(
        dir.join("manifest.json"),
        json::to_string_pretty(&manifest),
    )
    .context("writing manifest.json")?;
    Ok(())
}

/// FNV-1a over the model name (stable across runs and platforms).
fn hash_name(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::ModelGraph;
    use crate::runtime::Manifest;

    #[test]
    fn generation_is_deterministic() {
        let a = std::env::temp_dir().join("flexserve-synth-det-a");
        let b = std::env::temp_dir().join("flexserve-synth-det-b");
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
        write_synthetic(&a, 7).unwrap();
        write_synthetic(&b, 7).unwrap();
        for f in ["manifest.json", "mlp.weights.f32"] {
            assert_eq!(
                std::fs::read(a.join(f)).unwrap(),
                std::fs::read(b.join(f)).unwrap(),
                "{f} differs between identical seeds"
            );
        }
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
    }

    #[test]
    fn synthetic_manifest_loads_and_verifies() {
        let dir = ensure_synthetic();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.models.len(), 3);
        assert_eq!(m.sample_elems(), 256);
        assert_eq!(m.num_classes(), 4);
        assert!(m.provenance.get("interchange").is_some());
        m.verify_all().expect("sidecar shas verify");
        for e in &m.models {
            assert_eq!(e.backend.as_deref(), Some("cpu"));
            assert!(!e.layers.is_empty());
            assert!(e.test_acc > 0.5);
            // The layer grammar loads into an executable graph.
            let g = ModelGraph::load(&m, e, true).unwrap();
            assert_eq!(g.in_dim, 256);
            assert_eq!(g.out_dim, 4);
        }
    }
}
