//! Quantized u8 inference backend.
//!
//! Weights are quantized **once at load** to asymmetric u8 with a
//! per-output-column scale/zero-point (column-wise min/max); inputs are
//! quantized per row on the fly (dynamic range). The inner product runs
//! entirely in u8×u8→i32 — 8-column accumulator blocks, the integer twin
//! of the f32 kernel in [`super::cpu`] — and dequantizes back to f32 only
//! at the layer boundary:
//!
//! ```text
//! Σ x·w = sx·sj · [ Σ qx·qw − zj·Σqx − zx·Σqw + n·zx·zj ]
//! ```
//!
//! The three correction terms cost one pass per row (`Σqx`) and a
//! load-time column sum (`Σqw`), so the hot loop is a pure integer dot.
//! Accuracy: argmax agreement with the f32 path is pinned ≥ threshold by
//! `tests/backend_differential.rs`.

use super::{Act, Backend, BackendKind, ModelGraph};
use crate::runtime::arena::BufferArena;
use crate::runtime::tensor::TensorView;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// One layer with pre-quantized weights.
struct QLayer {
    in_dim: usize,
    out_dim: usize,
    act: Act,
    /// Row-major `[in_dim][out_dim]`, same layout as the f32 weights.
    qw: Vec<u8>,
    /// Per-output-column dequant scale.
    wscale: Vec<f32>,
    /// Per-output-column zero point.
    wzero: Vec<i32>,
    /// Per-output-column `Σ_k qw[k][j]` (load-time correction term).
    col_qsum: Vec<i32>,
    /// f32 bias, applied after dequantization.
    bias: Vec<f32>,
}

/// A model's quantized weights, shared across its bucket slots.
pub struct QuantModel {
    layers: Vec<QLayer>,
    pub in_dim: usize,
    pub out_dim: usize,
    pub max_dim: usize,
}

impl QuantModel {
    /// Quantize every layer of a loaded f32 graph.
    pub fn from_graph(g: &ModelGraph) -> QuantModel {
        let layers = g
            .layers
            .iter()
            .map(|l| {
                let w = &g.weights[l.w_off..l.w_off + l.in_dim * l.out_dim];
                let bias = g.weights[l.b_off..l.b_off + l.out_dim].to_vec();
                let mut wscale = vec![0f32; l.out_dim];
                let mut wzero = vec![0i32; l.out_dim];
                for j in 0..l.out_dim {
                    let mut lo = f32::INFINITY;
                    let mut hi = f32::NEG_INFINITY;
                    for k in 0..l.in_dim {
                        let v = w[k * l.out_dim + j];
                        lo = lo.min(v);
                        hi = hi.max(v);
                    }
                    // Degenerate column (constant weight) → any scale works.
                    let scale = ((hi - lo) / 255.0).max(1e-12);
                    wscale[j] = scale;
                    wzero[j] = (-lo / scale).round().clamp(0.0, 255.0) as i32;
                }
                let mut qw = vec![0u8; l.in_dim * l.out_dim];
                let mut col_qsum = vec![0i32; l.out_dim];
                for k in 0..l.in_dim {
                    for j in 0..l.out_dim {
                        let q = (w[k * l.out_dim + j] / wscale[j] + wzero[j] as f32)
                            .round()
                            .clamp(0.0, 255.0) as u8;
                        qw[k * l.out_dim + j] = q;
                        col_qsum[j] += q as i32;
                    }
                }
                QLayer {
                    in_dim: l.in_dim,
                    out_dim: l.out_dim,
                    act: l.act,
                    qw,
                    wscale,
                    wzero,
                    col_qsum,
                    bias,
                }
            })
            .collect();
        QuantModel {
            layers,
            in_dim: g.in_dim,
            out_dim: g.out_dim,
            max_dim: g.max_dim,
        }
    }
}

/// One (model × bucket) quantized slot. Owns its u8 input scratch
/// (allocated at construction, sized to the widest layer) so the
/// steady-state path allocates nothing.
pub struct QuantBackend {
    model: Arc<QuantModel>,
    bucket: usize,
    /// Quantized row buffer, `max_dim` wide (one row at a time).
    qx: Vec<u8>,
}

impl QuantBackend {
    pub fn new(model: Arc<QuantModel>, bucket: usize) -> QuantBackend {
        let qx = vec![0u8; model.max_dim];
        QuantBackend { model, bucket, qx }
    }
}

/// Quantize one f32 row to u8 with a dynamic asymmetric range; returns
/// `(scale, zero_point, Σq)`.
fn quantize_row(x: &[f32], q: &mut [u8]) -> (f32, i32, i32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in x {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let scale = ((hi - lo) / 255.0).max(1e-12);
    let zero = (-lo / scale).round().clamp(0.0, 255.0) as i32;
    let mut qsum = 0i32;
    for (qv, &v) in q.iter_mut().zip(x) {
        let qq = (v / scale + zero as f32).round().clamp(0.0, 255.0) as u8;
        *qv = qq;
        qsum += qq as i32;
    }
    (scale, zero, qsum)
}

/// One quantized layer over one row: integer dot in 8-column blocks,
/// dequant + bias + activation into `y`.
fn qlayer_row(l: &QLayer, qx: &[u8], sx: f32, zx: i32, qsum: i32, y: &mut [f32]) {
    let n = l.in_dim as i32;
    let out = l.out_dim;
    let main_end = out / 8 * 8;
    let mut jc = 0;
    while jc < main_end {
        let mut acc = [0i32; 8];
        for (k, &xq) in qx.iter().enumerate() {
            let xq = xq as i32;
            let wr = &l.qw[k * out + jc..k * out + jc + 8];
            for t in 0..8 {
                acc[t] += xq * wr[t] as i32;
            }
        }
        for t in 0..8 {
            let j = jc + t;
            let corr = acc[t] - l.wzero[j] * qsum - zx * l.col_qsum[j] + n * zx * l.wzero[j];
            let v = sx * l.wscale[j] * corr as f32 + l.bias[j];
            y[j] = l.act.apply(v);
        }
        jc += 8;
    }
    for j in main_end..out {
        let mut acc = 0i32;
        for (k, &xq) in qx.iter().enumerate() {
            acc += xq as i32 * l.qw[k * out + j] as i32;
        }
        let corr = acc - l.wzero[j] * qsum - zx * l.col_qsum[j] + n * zx * l.wzero[j];
        let v = sx * l.wscale[j] * corr as f32 + l.bias[j];
        y[j] = l.act.apply(v);
    }
}

impl Backend for QuantBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Quant
    }

    fn run(&mut self, feed: &[f32], arena: &mut BufferArena) -> Result<TensorView> {
        let m = Arc::clone(&self.model);
        let rows = self.bucket;
        ensure!(
            feed.len() == rows * m.in_dim,
            "quant backend: feed {} != bucket {} x in_dim {}",
            feed.len(),
            rows,
            m.in_dim
        );
        let nl = m.layers.len();
        let mut cur = arena.scratch(rows * m.max_dim);
        let mut nxt = arena.scratch(rows * m.max_dim);
        let mut src: &[f32] = feed;
        let mut out = None;
        for (i, l) in m.layers.iter().enumerate() {
            let last = i + 1 == nl;
            if last {
                out = Some(arena.with_output(rows * l.out_dim, |y| {
                    for r in 0..rows {
                        let xr = &src[r * l.in_dim..(r + 1) * l.in_dim];
                        let q = &mut self.qx[..l.in_dim];
                        let (sx, zx, qsum) = quantize_row(xr, q);
                        qlayer_row(l, q, sx, zx, qsum, &mut y[r * l.out_dim..(r + 1) * l.out_dim]);
                    }
                }));
            } else {
                for r in 0..rows {
                    let xr = &src[r * l.in_dim..(r + 1) * l.in_dim];
                    let q = &mut self.qx[..l.in_dim];
                    let (sx, zx, qsum) = quantize_row(xr, q);
                    qlayer_row(l, q, sx, zx, qsum, &mut nxt[r * l.out_dim..(r + 1) * l.out_dim]);
                }
                std::mem::swap(&mut cur, &mut nxt);
                src = &cur[..rows * l.out_dim];
            }
        }
        arena.restore(cur);
        arena.restore(nxt);
        Ok(out.expect("graphs have >= 1 layer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::Layer;
    use crate::util::Prng;

    fn graph(dims: &[usize], seed: u64) -> ModelGraph {
        let mut prng = Prng::new(seed);
        let mut layers = Vec::new();
        let mut store = Vec::new();
        for w in dims.windows(2) {
            let (i, o) = (w[0], w[1]);
            let w_off = store.len();
            for _ in 0..i * o {
                store.push((prng.normal() as f32) / (i as f32).sqrt());
            }
            let b_off = store.len();
            for _ in 0..o {
                store.push(prng.normal() as f32 * 0.1);
            }
            layers.push(Layer {
                in_dim: i,
                out_dim: o,
                act: Act::Relu,
                w_off,
                b_off,
            });
        }
        layers.last_mut().unwrap().act = Act::Linear;
        ModelGraph::new(layers, store.into()).unwrap()
    }

    #[test]
    fn integer_weights_round_trip_exactly() {
        // Weights already on a 255-step grid → quantization is lossless,
        // so the integer path must reproduce f32 almost exactly (only the
        // dynamic input quantization adds noise; integer inputs kill that
        // too).
        let store: Vec<f32> = vec![1.0, 2.0, -1.0, 0.0, 3.0, 1.0, 0.5, -0.5];
        let g = ModelGraph::new(
            vec![Layer {
                in_dim: 3,
                out_dim: 2,
                act: Act::Linear,
                w_off: 0,
                b_off: 6,
            }],
            store.into(),
        )
        .unwrap();
        let m = Arc::new(QuantModel::from_graph(&g));
        let mut be = QuantBackend::new(m, 1);
        let mut arena = BufferArena::new(1);
        let x = [10.0f32, 20.0, 30.0];
        let want = g.forward_reference(&x, 1);
        let got = be.run(&x, &mut arena).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 0.05, "{a} vs {b} (want {want:?})");
        }
    }

    #[test]
    fn argmax_agrees_with_f32_reference() {
        let g = graph(&[32, 24, 4], 99);
        let m = Arc::new(QuantModel::from_graph(&g));
        let mut be = QuantBackend::new(m, 1);
        let mut arena = BufferArena::new(1);
        let mut prng = Prng::new(123);
        let mut agree = 0;
        let trials = 100;
        for _ in 0..trials {
            let x: Vec<f32> = (0..32).map(|_| prng.normal() as f32).collect();
            let want = g.forward_reference(&x, 1);
            let got = be.run(&x, &mut arena).unwrap();
            let am = |v: &[f32]| {
                v.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            };
            if am(&want) == am(&got) {
                agree += 1;
            }
        }
        assert!(agree >= 90, "argmax agreement {agree}/{trials} < 90%");
    }

    #[test]
    fn batched_rows_are_independent() {
        let g = graph(&[8, 6, 3], 5);
        let m = Arc::new(QuantModel::from_graph(&g));
        let mut arena = BufferArena::new(1);
        let mut prng = Prng::new(6);
        let x: Vec<f32> = (0..4 * 8).map(|_| prng.normal() as f32).collect();
        let mut b4 = QuantBackend::new(Arc::clone(&m), 4);
        let batched = b4.run(&x, &mut arena).unwrap();
        let mut b1 = QuantBackend::new(m, 1);
        for r in 0..4 {
            let single = b1.run(&x[r * 8..(r + 1) * 8], &mut arena).unwrap();
            for (a, b) in single.iter().zip(&batched[r * 3..(r + 1) * 3]) {
                assert!((a - b).abs() < 1e-6, "row {r}: {a} vs {b}");
            }
        }
    }
}
