//! Pure-Rust SIMD CPU backend: blocked f32 matmul with intra-op
//! parallelism over a tiny rayon-free worker set.
//!
//! The kernel accumulates 8 output columns at a time into a `[f32; 8]`
//! register block — the exact shape LLVM auto-vectorizes to one AVX/NEON
//! FMA per step — walking the row-major `[in][out]` weight matrix
//! sequentially (unit-stride loads, no gather). Large layers split across
//! [`CpuWorkers`]: persistent threads woken through a Mutex+Condvar epoch
//! barrier, handed a raw pointer to the caller's stack closure — a scoped
//! fork/join that performs **zero allocations per dispatch**, which is
//! what lets the allocation-counting harness pin the whole flush at zero.

use super::{Act, Backend, BackendKind, Layer, ModelGraph};
use crate::runtime::arena::BufferArena;
use crate::runtime::tensor::TensorView;
use anyhow::{ensure, Result};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Below this many multiply-accumulates a layer runs inline — waking the
/// worker set costs more than the matmul.
const PAR_MIN_MACS: usize = 32_768;

/// Worker-count heuristic when the config leaves `cpu_workers` at 0:
/// assume 2-way SMT (physical ≈ logical/2), clamped to [1, 8] so several
/// device workers can coexist without oversubscribing the box.
pub fn auto_workers() -> usize {
    let logical = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    (logical / 2).clamp(1, 8)
}

/// The closure pointer handed to workers. The barrier protocol guarantees
/// the pointee outlives every dereference: `scope` does not return until
/// all workers have finished the epoch.
#[derive(Clone, Copy)]
struct Task(*const (dyn Fn(usize) + Sync));
unsafe impl Send for Task {}

struct Ctrl {
    epoch: u64,
    remaining: usize,
    task: Option<Task>,
    poisoned: bool,
    shutdown: bool,
}

struct Shared {
    ctrl: Mutex<Ctrl>,
    work: Condvar,
    done: Condvar,
}

/// A fixed set of `n` compute lanes: `n - 1` persistent threads plus the
/// calling thread. [`scope`](CpuWorkers::scope) runs `f(part)` once for
/// every `part in 0..n` and returns when all parts are done.
pub struct CpuWorkers {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
    n: usize,
}

impl CpuWorkers {
    /// `n = 0` selects [`auto_workers`].
    pub fn new(n: usize) -> CpuWorkers {
        let n = if n == 0 { auto_workers() } else { n };
        let shared = Arc::new(Shared {
            ctrl: Mutex::new(Ctrl {
                epoch: 0,
                remaining: 0,
                task: None,
                poisoned: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let mut handles = Vec::new();
        for i in 1..n {
            let sh = Arc::clone(&shared);
            handles.push(
                thread::Builder::new()
                    .name(format!("flexserve-cpu-{i}"))
                    .spawn(move || worker_loop(sh, i))
                    .expect("spawning cpu worker"),
            );
        }
        CpuWorkers {
            shared,
            handles,
            n,
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Fork/join: run `f(part)` for each `part in 0..len()` — part 0 on
    /// the calling thread — and return once every part completed.
    /// Panics (poisoning the pool) if any worker's part panicked.
    pub fn scope(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.n == 1 {
            f(0);
            return;
        }
        // Erase the stack lifetime; the epoch barrier below re-establishes
        // it (no worker touches the pointer after `remaining` hits 0).
        let task: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        {
            let mut c = self.shared.ctrl.lock().unwrap();
            c.task = Some(Task(task));
            c.epoch += 1;
            c.remaining = self.n - 1;
            self.shared.work.notify_all();
        }
        f(0);
        let mut c = self.shared.ctrl.lock().unwrap();
        while c.remaining > 0 {
            c = self.shared.done.wait(c).unwrap();
        }
        c.task = None;
        if c.poisoned {
            c.poisoned = false;
            drop(c);
            panic!("cpu worker panicked during a parallel layer");
        }
    }
}

impl Drop for CpuWorkers {
    fn drop(&mut self) {
        {
            let mut c = self.shared.ctrl.lock().unwrap();
            c.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, part: usize) {
    let mut seen = 0u64;
    let mut c = shared.ctrl.lock().unwrap();
    loop {
        while !c.shutdown && (c.epoch == seen || c.task.is_none()) {
            c = shared.work.wait(c).unwrap();
        }
        if c.shutdown {
            return;
        }
        seen = c.epoch;
        let task = c.task.expect("task set with epoch");
        drop(c);
        // A panicking part must still reach the decrement or scope() would
        // hang; the poison flag re-raises it on the calling thread.
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (*task.0)(part)
        }))
        .is_ok();
        c = shared.ctrl.lock().unwrap();
        if !ok {
            c.poisoned = true;
        }
        c.remaining -= 1;
        if c.remaining == 0 {
            shared.done.notify_all();
        }
    }
}

/// Raw output cursor shared across worker parts. Each (row, col) cell is
/// written by exactly one part (disjoint row or column ranges), so the
/// aliasing is write-once and race-free.
struct OutPtr(*mut f32);
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// Compute `y[r][j] = act(x[r]·W[:,j] + b[j])` for `r in r0..r1`,
/// `j in j0..j1`. Weights are row-major `[in_dim][out_dim]`, so the inner
/// loop streams 8 adjacent columns per step into a `[f32; 8]` accumulator
/// block (auto-vectorized), with a scalar tail for `out_dim % 8`.
#[allow(clippy::too_many_arguments)]
fn dense_block(
    x: &[f32],
    in_dim: usize,
    out_dim: usize,
    w: &[f32],
    b: &[f32],
    act: Act,
    r0: usize,
    r1: usize,
    j0: usize,
    j1: usize,
    y: &OutPtr,
) {
    let main_end = j0 + (j1 - j0) / 8 * 8;
    for r in r0..r1 {
        let xr = &x[r * in_dim..(r + 1) * in_dim];
        let mut jc = j0;
        while jc < main_end {
            let mut acc = [0f32; 8];
            for (k, &xv) in xr.iter().enumerate() {
                let wr = &w[k * out_dim + jc..k * out_dim + jc + 8];
                for t in 0..8 {
                    acc[t] += xv * wr[t];
                }
            }
            for t in 0..8 {
                let v = act.apply(acc[t] + b[jc + t]);
                unsafe { *y.0.add(r * out_dim + jc + t) = v };
            }
            jc += 8;
        }
        for j in main_end..j1 {
            let mut acc = 0f32;
            for (k, &xv) in xr.iter().enumerate() {
                acc += xv * w[k * out_dim + j];
            }
            let v = act.apply(acc + b[j]);
            unsafe { *y.0.add(r * out_dim + j) = v };
        }
    }
}

/// One dense layer over `rows` rows of `x`, into `y` (`rows × out_dim`).
/// Splits across the worker set by rows (or by columns when the batch is
/// smaller than the lane count); small layers run inline.
pub(crate) fn forward_layer(
    g: &ModelGraph,
    l: &Layer,
    x: &[f32],
    rows: usize,
    y: &mut [f32],
    workers: &CpuWorkers,
) {
    debug_assert!(x.len() >= rows * l.in_dim);
    debug_assert_eq!(y.len(), rows * l.out_dim);
    let w = &g.weights[l.w_off..l.w_off + l.in_dim * l.out_dim];
    let b = &g.weights[l.b_off..l.b_off + l.out_dim];
    let yp = OutPtr(y.as_mut_ptr());
    let n = workers.len();
    let macs = rows * l.in_dim * l.out_dim;
    if n == 1 || macs < PAR_MIN_MACS {
        dense_block(x, l.in_dim, l.out_dim, w, b, l.act, 0, rows, 0, l.out_dim, &yp);
    } else if rows >= n {
        workers.scope(&|part| {
            let r0 = rows * part / n;
            let r1 = rows * (part + 1) / n;
            dense_block(x, l.in_dim, l.out_dim, w, b, l.act, r0, r1, 0, l.out_dim, &yp);
        });
    } else {
        workers.scope(&|part| {
            let j0 = l.out_dim * part / n;
            let j1 = l.out_dim * (part + 1) / n;
            dense_block(x, l.in_dim, l.out_dim, w, b, l.act, 0, rows, j0, j1, &yp);
        });
    }
}

/// One (model × bucket) CPU slot. The graph and worker set are shared
/// across a model's buckets; only the bucket-shaped dimensions differ.
pub struct CpuBackend {
    graph: Arc<ModelGraph>,
    bucket: usize,
    workers: Arc<CpuWorkers>,
}

impl CpuBackend {
    pub fn new(graph: Arc<ModelGraph>, bucket: usize, workers: Arc<CpuWorkers>) -> CpuBackend {
        CpuBackend {
            graph,
            bucket,
            workers,
        }
    }
}

impl Backend for CpuBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Cpu
    }

    fn run(&mut self, feed: &[f32], arena: &mut BufferArena) -> Result<TensorView> {
        let g = &self.graph;
        let rows = self.bucket;
        ensure!(
            feed.len() == rows * g.in_dim,
            "cpu backend: feed {} != bucket {} x in_dim {}",
            feed.len(),
            rows,
            g.in_dim
        );
        let nl = g.layers.len();
        // Ping-pong scratch for hidden activations; the final layer writes
        // straight into an arena-shared output buffer.
        let mut cur = arena.scratch(rows * g.max_dim);
        let mut nxt = arena.scratch(rows * g.max_dim);
        let mut src: &[f32] = feed;
        let mut out = None;
        for (i, l) in g.layers.iter().enumerate() {
            if i + 1 == nl {
                out = Some(arena.with_output(rows * l.out_dim, |y| {
                    forward_layer(g, l, src, rows, y, &self.workers)
                }));
            } else {
                forward_layer(g, l, src, rows, &mut nxt[..rows * l.out_dim], &self.workers);
                std::mem::swap(&mut cur, &mut nxt);
                src = &cur[..rows * l.out_dim];
            }
        }
        arena.restore(cur);
        arena.restore(nxt);
        Ok(out.expect("graphs have >= 1 layer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn random_graph(prng: &mut Prng, dims: &[usize], act_last: Act) -> ModelGraph {
        let mut layers = Vec::new();
        let mut store = Vec::new();
        for w in dims.windows(2) {
            let (i, o) = (w[0], w[1]);
            let w_off = store.len();
            for _ in 0..i * o {
                store.push((prng.normal() as f32) / (i as f32).sqrt());
            }
            let b_off = store.len();
            for _ in 0..o {
                store.push(prng.normal() as f32 * 0.1);
            }
            layers.push(Layer {
                in_dim: i,
                out_dim: o,
                act: Act::Relu,
                w_off,
                b_off,
            });
        }
        layers.last_mut().unwrap().act = act_last;
        ModelGraph::new(layers, store.into()).unwrap()
    }

    #[test]
    fn workers_run_every_part_once() {
        let w = CpuWorkers::new(4);
        let counts: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..50 {
            w.scope(&|p| {
                counts[p].fetch_add(1, Ordering::Relaxed);
            });
        }
        for c in &counts {
            assert_eq!(c.load(Ordering::Relaxed), 50);
        }
    }

    #[test]
    fn workers_single_lane_runs_inline() {
        let w = CpuWorkers::new(1);
        let hit = AtomicUsize::new(0);
        w.scope(&|p| {
            assert_eq!(p, 0);
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let w = CpuWorkers::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            w.scope(&|p| {
                if p == 1 {
                    panic!("injected");
                }
            });
        }));
        assert!(r.is_err());
        // The pool recovers for the next epoch.
        let ok = AtomicUsize::new(0);
        w.scope(&|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn kernel_matches_reference_including_tails() {
        let mut prng = Prng::new(11);
        // 10 output cols exercises the % 8 scalar tail; 3 layers exercise
        // the ping-pong; relu + linear both covered.
        let g = random_graph(&mut prng, &[12, 10, 9, 5], Act::Linear);
        let workers = CpuWorkers::new(1);
        for rows in [1, 2, 7] {
            let x: Vec<f32> = (0..rows * 12).map(|_| prng.normal() as f32).collect();
            let want = g.forward_reference(&x, rows);
            let mut src: Vec<f32> = x.clone();
            let mut y = Vec::new();
            for l in &g.layers {
                y = vec![0.0; rows * l.out_dim];
                forward_layer(&g, l, &src, rows, &mut y, &workers);
                src = y.clone();
            }
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "rows={rows}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn parallel_split_equals_serial() {
        let mut prng = Prng::new(7);
        // Big enough to clear PAR_MIN_MACS: 8 x 64 x 80 = 40960 MACs.
        let g = random_graph(&mut prng, &[64, 80], Act::Relu);
        let x: Vec<f32> = (0..8 * 64).map(|_| prng.normal() as f32).collect();
        let serial = CpuWorkers::new(1);
        let par = CpuWorkers::new(3);
        let l = &g.layers[0];
        // Row split (rows >= lanes) and column split (rows < lanes).
        for rows in [8usize, 2] {
            let mut ys = vec![0.0; rows * 80];
            let mut yp = vec![0.0; rows * 80];
            forward_layer(&g, l, &x[..rows * 64], rows, &mut ys, &serial);
            forward_layer(&g, l, &x[..rows * 64], rows, &mut yp, &par);
            assert_eq!(ys, yp, "rows={rows}");
        }
    }

    #[test]
    fn backend_run_matches_reference_through_arena() {
        let mut prng = Prng::new(3);
        let g = Arc::new(random_graph(&mut prng, &[16, 12, 4], Act::Linear));
        let workers = Arc::new(CpuWorkers::new(2));
        let mut arena = BufferArena::new(1);
        let mut be = CpuBackend::new(Arc::clone(&g), 4, workers);
        let feed: Vec<f32> = (0..4 * 16).map(|_| prng.normal() as f32).collect();
        let want = g.forward_reference(&feed, 4);
        let got = be.run(&feed, &mut arena).unwrap();
        assert_eq!(got.len(), 16);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
        // Second run recycles: the output shelf hit requires dropping the
        // first view.
        drop(got);
        let before = arena.misses();
        let got2 = be.run(&feed, &mut arena).unwrap();
        assert_eq!(arena.misses(), before, "steady-state run allocates no new buffers");
        assert_eq!(got2.len(), 16);
    }

    #[test]
    fn backend_rejects_wrong_feed_len() {
        let mut prng = Prng::new(5);
        let g = Arc::new(random_graph(&mut prng, &[4, 2], Act::Linear));
        let mut be = CpuBackend::new(g, 2, Arc::new(CpuWorkers::new(1)));
        let mut arena = BufferArena::new(1);
        assert!(be.run(&[0.0; 7], &mut arena).is_err());
    }
}
