//! The XLA/PJRT backend: the seed executor's compiled-HLO path, moved
//! behind the [`Backend`] trait byte-compatibly. Everything device-shaped
//! about the old `run_one` lives here unchanged: single-copy literal
//! creation straight into the batched shape, tuple-1 readback, f32 out.
//!
//! PJRT handles are `!Send`, which is why [`Backend`] itself is not
//! `Send`: the device thread owns every instance.

use super::{Backend, BackendKind};
use crate::runtime::arena::BufferArena;
use crate::runtime::tensor::TensorView;
use anyhow::{Context, Result};

pub struct XlaBackend {
    exe: ::xla::PjRtLoadedExecutable,
    /// Full literal dims: `[bucket, H, W, C]`.
    dims: Vec<usize>,
    bucket: usize,
}

impl XlaBackend {
    pub fn new(exe: ::xla::PjRtLoadedExecutable, bucket: usize, input_shape: &[usize]) -> XlaBackend {
        let mut dims = vec![bucket];
        dims.extend(input_shape);
        XlaBackend { exe, dims, bucket }
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }
}

impl Backend for XlaBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Xla
    }

    fn run(&mut self, feed: &[f32], _arena: &mut BufferArena) -> Result<TensorView> {
        // Single-copy literal creation straight into the batched shape
        // (§Perf L3#3: vec1+reshape copied the payload twice).
        let bytes = unsafe {
            std::slice::from_raw_parts(feed.as_ptr() as *const u8, std::mem::size_of_val(feed))
        };
        let input = ::xla::Literal::create_from_shape_and_untyped_data(
            ::xla::ElementType::F32,
            &self.dims,
            bytes,
        )
        .context("creating input literal")?;
        let result = self
            .exe
            .execute::<::xla::Literal>(&[input])
            .context("PJRT execute")?[0][0]
            .to_literal_sync()
            .context("device→host readback")?;
        // aot.py lowers with return_tuple=True → 1-tuple of logits.
        let logits = result.to_tuple1().context("unwrapping output tuple")?;
        let v = logits.to_vec::<f32>().context("logits to f32 vec")?;
        // The device readback owns its allocation; wrap it zero-copy. The
        // arena is not used — recycling device literals is PJRT's job.
        Ok(TensorView::from(v))
    }
}
