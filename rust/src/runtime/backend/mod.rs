//! Pluggable execution backends.
//!
//! The executor used to call XLA directly; now every (model × bucket) slot
//! holds a boxed [`Backend`] trait object and the device thread dispatches
//! through it. Three implementations ship:
//!
//! | backend | compute                         | needs                     |
//! |---------|---------------------------------|---------------------------|
//! | `xla`   | compiled HLO via PJRT           | `*.hlo.txt` artifacts     |
//! | `cpu`   | blocked f32 matmul, 8-wide, intra-op parallel | manifest layer grammar + f32 weights sidecar |
//! | `quant` | u8×u8→i32 with per-column scale/zero-point, f32 at the boundary | same as `cpu` |
//!
//! Backends are deliberately **not** `Send`: like the XLA handles before
//! them, each instance is owned by exactly one device thread, which also
//! owns the [`BufferArena`] their outputs are carved from. Selection
//! precedence (first hit wins): `--backend` global override → per-model
//! config override → the manifest entry's `"backend"` → `xla`.

use super::arena::BufferArena;
use super::manifest::{Manifest, ModelEntry};
use super::tensor::TensorView;
use anyhow::{bail, Context, Result};
use sha2::{Digest, Sha256};
use std::fmt;
use std::sync::Arc;

pub mod cpu;
pub mod quant;
pub mod xla;

pub use cpu::{CpuBackend, CpuWorkers};
pub use quant::{QuantBackend, QuantModel};
pub use xla::XlaBackend;

/// Which implementation serves a slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Xla,
    Cpu,
    Quant,
}

impl BackendKind {
    pub fn as_str(self) -> &'static str {
        match self {
            BackendKind::Xla => "xla",
            BackendKind::Cpu => "cpu",
            BackendKind::Quant => "quant",
        }
    }

    /// Parse a config/manifest/CLI spelling. `None` for unknown names —
    /// callers turn that into [`BackendUnsupported`] with context.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "xla" => Some(BackendKind::Xla),
            "cpu" => Some(BackendKind::Cpu),
            "quant" | "u8" => Some(BackendKind::Quant),
            _ => None,
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Typed marker: a manifest/config requested an execution backend this
/// server cannot serve for that model (unknown name, or a cpu/quant
/// request for a model that ships no layer grammar). Travels through
/// `anyhow` like [`super::WorkerCrashed`] so the coordinator can recover
/// it into the `model.backend_unsupported` 409 taxonomy row.
#[derive(Debug, Clone)]
pub struct BackendUnsupported {
    pub model: String,
    pub backend: String,
    pub detail: String,
}

impl BackendUnsupported {
    pub fn new(
        model: impl Into<String>,
        backend: impl Into<String>,
        detail: impl Into<String>,
    ) -> BackendUnsupported {
        BackendUnsupported {
            model: model.into(),
            backend: backend.into(),
            detail: detail.into(),
        }
    }
}

impl fmt::Display for BackendUnsupported {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "model '{}': backend '{}' unsupported: {}",
            self.model, self.backend, self.detail
        )
    }
}

impl std::error::Error for BackendUnsupported {}

/// One executable slot: a model specialized to one batch bucket.
///
/// `run` executes a full bucket-shaped forward: `feed` holds
/// `bucket × sample_elems` normalized inputs (already padded), the return
/// view holds `bucket × classes` logits carved from the arena (or, for
/// XLA, wrapped from the device readback). Implementations must not
/// allocate on the steady-state path — `tests/alloc_counting.rs` pins
/// this for `cpu` and `quant`.
pub trait Backend {
    fn kind(&self) -> BackendKind;
    fn run(&mut self, feed: &[f32], arena: &mut BufferArena) -> Result<TensorView>;
}

/// Resolve which backend a slot should use. Precedence: global `--backend`
/// override, then the per-model config override, then the manifest entry,
/// then XLA. `"auto"` at any level defers to the next.
pub fn select_kind(
    global: Option<&str>,
    per_model: Option<&str>,
    entry: Option<&str>,
    model: &str,
) -> Result<BackendKind> {
    for (src, spec) in [
        ("--backend", global),
        ("config override", per_model),
        ("manifest", entry),
    ] {
        match spec {
            None | Some("auto") | Some("") => continue,
            Some(name) => {
                return BackendKind::parse(name).ok_or_else(|| {
                    BackendUnsupported::new(
                        model,
                        name,
                        format!("unknown backend name (from {src}); known: xla, cpu, quant"),
                    )
                    .into()
                })
            }
        }
    }
    Ok(BackendKind::Xla)
}

/// Activation in the manifest layer grammar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Linear,
    Relu,
}

impl Act {
    fn parse(s: &str) -> Option<Act> {
        match s {
            "" | "none" | "linear" => Some(Act::Linear),
            "relu" => Some(Act::Relu),
            _ => None,
        }
    }

    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Act::Linear => v,
            Act::Relu => v.max(0.0),
        }
    }
}

/// One dense layer resolved against the weights sidecar. Weights are
/// row-major `[in_dim][out_dim]` at `w_off`; bias is `[out_dim]` at `b_off`
/// (both offsets in floats).
#[derive(Debug, Clone)]
pub struct Layer {
    pub in_dim: usize,
    pub out_dim: usize,
    pub act: Act,
    pub w_off: usize,
    pub b_off: usize,
}

/// A model's full linear/MLP grammar plus its flat f32 weights — the
/// shared substrate the `cpu` and `quant` backends execute. One graph is
/// loaded per model and shared (`Arc`) across its bucket slots.
#[derive(Debug)]
pub struct ModelGraph {
    pub layers: Vec<Layer>,
    pub weights: Arc<[f32]>,
    pub in_dim: usize,
    pub out_dim: usize,
    /// Widest activation in the chain — sizes per-row scratch.
    pub max_dim: usize,
}

impl ModelGraph {
    /// Validate a layer chain against its weights buffer.
    pub fn new(layers: Vec<Layer>, weights: Arc<[f32]>) -> Result<ModelGraph> {
        if layers.is_empty() {
            bail!("layer grammar is empty");
        }
        let mut max_dim = 0;
        for (i, l) in layers.iter().enumerate() {
            if l.in_dim == 0 || l.out_dim == 0 {
                bail!("layer {i}: zero dimension");
            }
            if i > 0 && layers[i - 1].out_dim != l.in_dim {
                bail!(
                    "layer {i}: in_dim {} != previous out_dim {}",
                    l.in_dim,
                    layers[i - 1].out_dim
                );
            }
            let w_end = l.w_off + l.in_dim * l.out_dim;
            let b_end = l.b_off + l.out_dim;
            if w_end > weights.len() || b_end > weights.len() {
                bail!(
                    "layer {i}: weights [{}..{w_end}) / bias [{}..{b_end}) exceed sidecar len {}",
                    l.w_off,
                    l.b_off,
                    weights.len()
                );
            }
            max_dim = max_dim.max(l.in_dim).max(l.out_dim);
        }
        Ok(ModelGraph {
            in_dim: layers[0].in_dim,
            out_dim: layers[layers.len() - 1].out_dim,
            max_dim,
            layers,
            weights,
        })
    }

    /// Load a model's graph from the manifest entry and its weights
    /// sidecar. `Err(BackendUnsupported)` when the entry carries no layer
    /// grammar; plain errors for IO/validation failures.
    pub fn load(manifest: &Manifest, entry: &ModelEntry, verify_sha: bool) -> Result<ModelGraph> {
        let kind_name = entry.backend.as_deref().unwrap_or("cpu");
        if entry.layers.is_empty() {
            return Err(BackendUnsupported::new(
                &entry.name,
                kind_name,
                "manifest entry has no linear/MLP layer grammar (\"layers\")",
            )
            .into());
        }
        let wref = entry.weights.as_ref().ok_or_else(|| {
            anyhow::Error::new(BackendUnsupported::new(
                &entry.name,
                kind_name,
                "manifest entry has no weights sidecar (\"weights\")",
            ))
        })?;
        let path = manifest.dir.join(&wref.file);
        let data = std::fs::read(&path).with_context(|| format!("reading weights {path:?}"))?;
        if verify_sha {
            let digest: String = Sha256::digest(&data)
                .iter()
                .map(|b| format!("{b:02x}"))
                .collect();
            if digest != wref.sha256 {
                bail!(
                    "provenance violation: {} sha256 {digest} != manifest {}",
                    wref.file,
                    wref.sha256
                );
            }
        }
        if data.len() % 4 != 0 {
            bail!("weights sidecar {} length {} not a multiple of 4", wref.file, data.len());
        }
        let mut weights = vec![0f32; data.len() / 4];
        for (i, c) in data.chunks_exact(4).enumerate() {
            weights[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        let mut layers = Vec::with_capacity(entry.layers.len());
        for (i, l) in entry.layers.iter().enumerate() {
            if l.op != "linear" {
                return Err(BackendUnsupported::new(
                    &entry.name,
                    kind_name,
                    format!("layer {i}: unsupported op '{}'", l.op),
                )
                .into());
            }
            let act = Act::parse(&l.act).ok_or_else(|| {
                anyhow::Error::new(BackendUnsupported::new(
                    &entry.name,
                    kind_name,
                    format!("layer {i}: unsupported activation '{}'", l.act),
                ))
            })?;
            layers.push(Layer {
                in_dim: l.in_dim,
                out_dim: l.out_dim,
                act,
                w_off: l.w_off,
                b_off: l.b_off,
            });
        }
        let graph = ModelGraph::new(layers, weights.into())
            .with_context(|| format!("model {}", entry.name))?;
        if graph.in_dim != manifest.sample_elems() {
            bail!(
                "model {}: first layer in_dim {} != sample elems {}",
                entry.name,
                graph.in_dim,
                manifest.sample_elems()
            );
        }
        if graph.out_dim != manifest.num_classes() {
            bail!(
                "model {}: last layer out_dim {} != classes {}",
                entry.name,
                graph.out_dim,
                manifest.num_classes()
            );
        }
        Ok(graph)
    }

    /// Plain scalar forward — the ground truth the blocked/quantized
    /// kernels are differentially tested against. Allocates freely; never
    /// on the serving path.
    pub fn forward_reference(&self, x: &[f32], rows: usize) -> Vec<f32> {
        assert_eq!(x.len(), rows * self.in_dim);
        let mut cur = x.to_vec();
        for l in &self.layers {
            let w = &self.weights[l.w_off..l.w_off + l.in_dim * l.out_dim];
            let b = &self.weights[l.b_off..l.b_off + l.out_dim];
            let mut next = vec![0f32; rows * l.out_dim];
            for r in 0..rows {
                for j in 0..l.out_dim {
                    let mut acc = b[j];
                    for k in 0..l.in_dim {
                        acc += cur[r * l.in_dim + k] * w[k * l.out_dim + j];
                    }
                    next[r * l.out_dim + j] = l.act.apply(acc);
                }
            }
            cur = next;
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_round_trips() {
        for k in [BackendKind::Xla, BackendKind::Cpu, BackendKind::Quant] {
            assert_eq!(BackendKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(BackendKind::parse("u8"), Some(BackendKind::Quant));
        assert_eq!(BackendKind::parse("tpu"), None);
    }

    #[test]
    fn select_precedence() {
        // Global override beats everything.
        assert_eq!(
            select_kind(Some("quant"), Some("cpu"), Some("xla"), "m").unwrap(),
            BackendKind::Quant
        );
        // Per-model config beats the manifest.
        assert_eq!(
            select_kind(None, Some("cpu"), Some("xla"), "m").unwrap(),
            BackendKind::Cpu
        );
        // Manifest entry.
        assert_eq!(
            select_kind(None, None, Some("cpu"), "m").unwrap(),
            BackendKind::Cpu
        );
        // Default.
        assert_eq!(select_kind(None, None, None, "m").unwrap(), BackendKind::Xla);
        // "auto" defers to the next level.
        assert_eq!(
            select_kind(Some("auto"), None, Some("quant"), "m").unwrap(),
            BackendKind::Quant
        );
    }

    #[test]
    fn select_unknown_is_typed_unsupported() {
        let err = select_kind(Some("tpu"), None, None, "cnn_s").unwrap_err();
        let u = err.downcast_ref::<BackendUnsupported>().expect("typed");
        assert_eq!(u.model, "cnn_s");
        assert_eq!(u.backend, "tpu");
    }

    fn tiny_graph() -> ModelGraph {
        // 2 → 2 identity-ish: W = [[1,0],[0,1]], b = [0.5, -0.5].
        let weights: Arc<[f32]> = vec![1.0, 0.0, 0.0, 1.0, 0.5, -0.5].into();
        ModelGraph::new(
            vec![Layer {
                in_dim: 2,
                out_dim: 2,
                act: Act::Linear,
                w_off: 0,
                b_off: 4,
            }],
            weights,
        )
        .unwrap()
    }

    #[test]
    fn reference_forward_computes() {
        let g = tiny_graph();
        let y = g.forward_reference(&[2.0, 3.0], 1);
        assert_eq!(y, vec![2.5, 2.5]);
    }

    #[test]
    fn graph_rejects_dim_mismatch() {
        let weights: Arc<[f32]> = vec![0.0; 16].into();
        let err = ModelGraph::new(
            vec![
                Layer {
                    in_dim: 2,
                    out_dim: 3,
                    act: Act::Relu,
                    w_off: 0,
                    b_off: 6,
                },
                Layer {
                    in_dim: 4, // != 3
                    out_dim: 1,
                    act: Act::Linear,
                    w_off: 9,
                    b_off: 13,
                },
            ],
            weights,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("previous out_dim"), "{err}");
    }

    #[test]
    fn graph_rejects_out_of_bounds_offsets() {
        let weights: Arc<[f32]> = vec![0.0; 4].into();
        let err = ModelGraph::new(
            vec![Layer {
                in_dim: 2,
                out_dim: 2,
                act: Act::Linear,
                w_off: 2, // 2+4 > 4
                b_off: 0,
            }],
            weights,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("exceed sidecar"), "{err}");
    }

    #[test]
    fn relu_applies() {
        assert_eq!(Act::Relu.apply(-1.0), 0.0);
        assert_eq!(Act::Relu.apply(2.0), 2.0);
        assert_eq!(Act::Linear.apply(-1.0), -1.0);
    }
}
