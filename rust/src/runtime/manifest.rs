//! The artifact manifest: the contract between `python/compile/aot.py` and
//! this runtime, including the provenance block the paper's motivation
//! calls for (cloud APIs give you none; FlexServe-RS pins every servable
//! byte by SHA-256).

use crate::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};
use sha2::{Digest, Sha256};
use std::path::{Path, PathBuf};

/// One HLO artifact (a model specialized to one batch bucket).
#[derive(Debug, Clone)]
pub struct ArtifactRef {
    pub bucket: usize,
    pub file: String,
    pub sha256: String,
    pub bytes: u64,
}

/// One dense layer in the optional linear/MLP grammar (the substrate the
/// `cpu`/`quant` backends execute). Offsets are in floats into the
/// weights sidecar: weights row-major `[in][out]` at `w_off`, bias
/// `[out]` at `b_off`.
#[derive(Debug, Clone)]
pub struct LayerRef {
    pub op: String,
    pub in_dim: usize,
    pub out_dim: usize,
    pub act: String,
    pub w_off: usize,
    pub b_off: usize,
}

/// The flat little-endian f32 weights sidecar backing [`LayerRef`]s,
/// sha-pinned like every other servable byte.
#[derive(Debug, Clone)]
pub struct WeightsRef {
    pub file: String,
    pub sha256: String,
    pub bytes: u64,
}

/// One servable model *version* (all its batch buckets). `name` is the
/// pool-facing **slot**: version 1 keeps the bare model name (the legacy
/// flat layout is byte-compatible), later versions are `"<model>@<v>"`
/// ([`slot_name`]). The registry store is the only producer of entries
/// with `version > 1`.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    /// Registry version this entry serves (1 = the flat-layout manifest).
    pub version: u32,
    pub param_count: u64,
    pub test_acc: f64,
    pub params_sha256: String,
    /// Sorted ascending by bucket.
    pub buckets: Vec<ArtifactRef>,
    /// Requested execution backend (`"xla"`, `"cpu"`, `"quant"`); `None`
    /// defers to config/CLI selection (default XLA).
    pub backend: Option<String>,
    /// Linear/MLP layer grammar; empty for XLA-only models.
    pub layers: Vec<LayerRef>,
    /// Weights sidecar backing `layers`.
    pub weights: Option<WeightsRef>,
}

impl ModelEntry {
    /// Smallest bucket that fits a batch of `n`, if any.
    pub fn bucket_for(&self, n: usize) -> Option<&ArtifactRef> {
        self.buckets.iter().find(|a| a.bucket >= n)
    }

    pub fn max_bucket(&self) -> usize {
        self.buckets.last().map(|a| a.bucket).unwrap_or(0)
    }

    /// Total artifact bytes across buckets (lifecycle introspection).
    pub fn artifact_bytes(&self) -> u64 {
        self.buckets.iter().map(|a| a.bytes).sum()
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub input_shape: Vec<usize>,
    pub classes: Vec<String>,
    pub norm_mean: f32,
    pub norm_std: f32,
    pub buckets: Vec<usize>,
    pub models: Vec<ModelEntry>,
    /// Raw provenance block (exposed verbatim on `GET /models`).
    pub provenance: Value,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        Self::from_value(dir, &v)
    }

    /// Parse a manifest from an already-parsed JSON document (the file
    /// contract between `aot.py` and this runtime; also used by tests).
    pub fn from_value(dir: PathBuf, v: &Value) -> Result<Manifest> {
        let fmt = v
            .get("format_version")
            .and_then(Value::as_u64)
            .ok_or_else(|| anyhow!("manifest: missing format_version"))?;
        if fmt != 1 {
            bail!("manifest: unsupported format_version {fmt}");
        }
        let input_shape = v
            .get("input_shape")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing input_shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad input_shape dim")))
            .collect::<Result<Vec<_>>>()?;
        let classes = v
            .get("classes")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing classes"))?
            .iter()
            .map(|c| {
                c.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("bad class name"))
            })
            .collect::<Result<Vec<_>>>()?;
        let norm_mean = v
            .path(&["normalize", "mean"])
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow!("manifest: missing normalize.mean"))? as f32;
        let norm_std = v
            .path(&["normalize", "std"])
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow!("manifest: missing normalize.std"))? as f32;
        let buckets = v
            .get("buckets")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing buckets"))?
            .iter()
            .map(|b| b.as_usize().ok_or_else(|| anyhow!("bad bucket")))
            .collect::<Result<Vec<_>>>()?;

        let mut models = Vec::new();
        for (name, m) in v
            .get("models")
            .and_then(Value::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing models"))?
        {
            // Leading underscores are reserved for protocol pseudo-models
            // (the /v2 `_ensemble` alias would silently shadow one).
            if name.starts_with('_') {
                bail!("model name '{name}' is reserved (names may not start with '_')");
            }
            // '@' is the registry's version-slot separator ("cnn_s@2"); a
            // literal '@' in a model name would collide with those slots.
            if name.contains('@') {
                bail!("model name '{name}' is reserved (names may not contain '@')");
            }
            let mut bucket_refs = Vec::new();
            for (bucket_s, b) in m
                .get("buckets")
                .and_then(Value::as_obj)
                .ok_or_else(|| anyhow!("model {name}: missing buckets"))?
            {
                bucket_refs.push(ArtifactRef {
                    bucket: bucket_s
                        .parse()
                        .with_context(|| format!("model {name}: bad bucket key"))?,
                    file: b
                        .get("file")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("model {name}: missing file"))?
                        .to_string(),
                    sha256: b
                        .get("sha256")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("model {name}: missing sha256"))?
                        .to_string(),
                    bytes: b.get("bytes").and_then(Value::as_u64).unwrap_or(0),
                });
            }
            bucket_refs.sort_by_key(|a| a.bucket);
            if bucket_refs.is_empty() {
                bail!("model {name}: no buckets");
            }
            let mut layers = Vec::new();
            if let Some(items) = m.get("layers").and_then(Value::as_arr) {
                for (i, l) in items.iter().enumerate() {
                    let dim = |key: &str| -> Result<usize> {
                        l.get(key).and_then(Value::as_usize).ok_or_else(|| {
                            anyhow!("model {name}: layer {i}: missing/bad '{key}'")
                        })
                    };
                    layers.push(LayerRef {
                        op: l
                            .get("op")
                            .and_then(Value::as_str)
                            .unwrap_or("linear")
                            .to_string(),
                        in_dim: dim("in")?,
                        out_dim: dim("out")?,
                        act: l.get("act").and_then(Value::as_str).unwrap_or("").to_string(),
                        w_off: dim("w_off")?,
                        b_off: dim("b_off")?,
                    });
                }
            }
            let weights = match m.get("weights") {
                Some(w) => Some(WeightsRef {
                    file: w
                        .get("file")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("model {name}: weights missing file"))?
                        .to_string(),
                    sha256: w
                        .get("sha256")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("model {name}: weights missing sha256"))?
                        .to_string(),
                    bytes: w.get("bytes").and_then(Value::as_u64).unwrap_or(0),
                }),
                None => None,
            };
            models.push(ModelEntry {
                name: name.clone(),
                version: 1,
                param_count: m.get("param_count").and_then(Value::as_u64).unwrap_or(0),
                test_acc: m.get("test_acc").and_then(Value::as_f64).unwrap_or(0.0),
                params_sha256: m
                    .get("params_sha256")
                    .and_then(Value::as_str)
                    .unwrap_or("")
                    .to_string(),
                buckets: bucket_refs,
                backend: m
                    .get("backend")
                    .and_then(Value::as_str)
                    .map(str::to_string),
                layers,
                weights,
            });
        }
        if models.is_empty() {
            bail!("manifest: no models");
        }
        models.sort_by(|a, b| a.name.cmp(&b.name));

        Ok(Manifest {
            dir,
            input_shape,
            classes,
            norm_mean,
            norm_std,
            buckets,
            models,
            provenance: v.get("provenance").cloned().unwrap_or(Value::Null),
        })
    }

    /// Elements per single input sample (e.g. 16*16*1 = 256).
    pub fn sample_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    pub fn model(&self, name: &str) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.name == name)
    }

    pub fn model_names(&self) -> Vec<String> {
        self.models.iter().map(|m| m.name.clone()).collect()
    }

    /// Absolute path of one artifact file.
    pub fn artifact_path(&self, a: &ArtifactRef) -> PathBuf {
        self.dir.join(&a.file)
    }

    /// Verify an artifact's SHA-256 against the manifest (provenance gate —
    /// refuses to serve bytes that aren't the ones the build signed).
    pub fn verify_artifact(&self, a: &ArtifactRef) -> Result<()> {
        let path = self.artifact_path(a);
        let data = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        let digest = hex(&Sha256::digest(&data));
        if digest != a.sha256 {
            bail!(
                "provenance violation: {} sha256 {digest} != manifest {}",
                a.file,
                a.sha256
            );
        }
        Ok(())
    }

    /// Verify every artifact (`flexserve verify` / server startup option).
    pub fn verify_all(&self) -> Result<()> {
        for m in &self.models {
            for a in &m.buckets {
                self.verify_artifact(a)
                    .with_context(|| format!("model {}", m.name))?;
            }
        }
        Ok(())
    }
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// The pool-facing slot id of one (model, version). Version 1 is the bare
/// model name so the legacy flat layout (and every `/v1` wire byte) stays
/// identical; later versions append `@<version>`.
pub fn slot_name(name: &str, version: u32) -> String {
    if version <= 1 {
        name.to_string()
    } else {
        format!("{name}@{version}")
    }
}

/// Inverse of [`slot_name`]: `(bare model name, version)`. Bare names are
/// version 1; malformed suffixes fall back to treating the whole string as
/// a bare name (manifest load rejects '@' in real model names, so this
/// only happens on strings that never were slots).
pub fn split_slot(slot: &str) -> (&str, u32) {
    match slot.rsplit_once('@') {
        Some((name, v)) => match v.parse::<u32>() {
            Ok(n) if n >= 2 && !name.is_empty() => (name, n),
            _ => (slot, 1),
        },
        None => (slot, 1),
    }
}

/// Default artifact dir: `$FLEXSERVE_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("FLEXSERVE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_value() -> Value {
        json::parse(
            r#"{
              "format_version": 1,
              "input_shape": [16, 16, 1],
              "classes": ["blank", "square", "cross", "disc"],
              "normalize": {"mean": 0.1307, "std": 0.3081},
              "buckets": [1, 4],
              "models": {
                "m1": {
                  "param_count": 100,
                  "test_acc": 0.9,
                  "params_sha256": "ab",
                  "buckets": {
                    "1": {"file": "m1_b1.hlo.txt", "sha256": "x", "bytes": 10},
                    "4": {"file": "m1_b4.hlo.txt", "sha256": "y", "bytes": 11}
                  }
                }
              },
              "provenance": {"generator": "test"}
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_fake_manifest() {
        let m = Manifest::from_value(PathBuf::from("/tmp"), &fake_manifest_value()).unwrap();
        assert_eq!(m.sample_elems(), 256);
        assert_eq!(m.num_classes(), 4);
        assert_eq!(m.models.len(), 1);
        let e = m.model("m1").unwrap();
        assert_eq!(e.buckets.len(), 2);
        assert_eq!(e.bucket_for(1).unwrap().bucket, 1);
        assert_eq!(e.bucket_for(2).unwrap().bucket, 4);
        assert_eq!(e.bucket_for(4).unwrap().bucket, 4);
        assert!(e.bucket_for(5).is_none());
        assert_eq!(e.max_bucket(), 4);
    }

    #[test]
    fn parses_backend_and_layer_grammar() {
        let v = json::parse(
            r#"{"format_version":1,"input_shape":[2],"classes":["a","b"],
                "normalize":{"mean":0,"std":1},"buckets":[1],
                "models":{"m":{"param_count":8,"test_acc":0.9,
                  "params_sha256":"s",
                  "backend":"cpu",
                  "layers":[{"op":"linear","in":2,"out":2,"act":"relu","w_off":0,"b_off":4}],
                  "weights":{"file":"m.weights.f32","sha256":"s","bytes":24},
                  "buckets":{"1":{"file":"m.weights.f32","sha256":"s","bytes":24}}}}}"#,
        )
        .unwrap();
        let m = Manifest::from_value(PathBuf::from("/tmp"), &v).unwrap();
        let e = m.model("m").unwrap();
        assert_eq!(e.backend.as_deref(), Some("cpu"));
        assert_eq!(e.layers.len(), 1);
        assert_eq!(e.layers[0].in_dim, 2);
        assert_eq!(e.layers[0].act, "relu");
        assert_eq!(e.layers[0].b_off, 4);
        assert_eq!(e.weights.as_ref().unwrap().file, "m.weights.f32");
    }

    #[test]
    fn backend_fields_default_empty() {
        // The legacy HLO-only manifest parses with no backend grammar.
        let m = Manifest::from_value(PathBuf::from("/tmp"), &fake_manifest_value()).unwrap();
        let e = m.model("m1").unwrap();
        assert!(e.backend.is_none());
        assert!(e.layers.is_empty());
        assert!(e.weights.is_none());
    }

    #[test]
    fn rejects_bad_version() {
        let mut v = fake_manifest_value();
        if let Value::Obj(members) = &mut v {
            members[0].1 = Value::Num(2.0);
        }
        assert!(Manifest::from_value(PathBuf::from("/tmp"), &v).is_err());
    }

    #[test]
    fn rejects_reserved_underscore_names() {
        // '_'-prefixed names are protocol pseudo-models (/v2 `_ensemble`).
        let v = json::parse(
            r#"{"format_version":1,"input_shape":[1],"classes":["a"],
                "normalize":{"mean":0,"std":1},"buckets":[1],
                "models":{"_ensemble":{"param_count":1,"test_acc":0.5,
                  "params_sha256":"x",
                  "buckets":{"1":{"file":"f","sha256":"s","bytes":1}}}}}"#,
        )
        .unwrap();
        let err = Manifest::from_value(PathBuf::from("/tmp"), &v).unwrap_err();
        assert!(format!("{err:#}").contains("reserved"), "{err:#}");
    }

    #[test]
    fn rejects_empty_models() {
        let v = json::parse(
            r#"{"format_version":1,"input_shape":[1],"classes":["a"],
                "normalize":{"mean":0,"std":1},"buckets":[1],"models":{}}"#,
        )
        .unwrap();
        assert!(Manifest::from_value(PathBuf::from("/tmp"), &v).is_err());
    }

    #[test]
    fn slot_names_round_trip() {
        assert_eq!(slot_name("cnn_s", 1), "cnn_s");
        assert_eq!(slot_name("cnn_s", 2), "cnn_s@2");
        assert_eq!(split_slot("cnn_s"), ("cnn_s", 1));
        assert_eq!(split_slot("cnn_s@2"), ("cnn_s", 2));
        assert_eq!(split_slot("cnn_s@17"), ("cnn_s", 17));
        // Degenerate suffixes fall back to bare names.
        assert_eq!(split_slot("a@0"), ("a@0", 1));
        assert_eq!(split_slot("a@1"), ("a@1", 1));
        assert_eq!(split_slot("a@x"), ("a@x", 1));
        assert_eq!(split_slot("@2"), ("@2", 1));
    }

    #[test]
    fn rejects_at_sign_names() {
        // '@' is the registry's version-slot separator.
        let v = json::parse(
            r#"{"format_version":1,"input_shape":[1],"classes":["a"],
                "normalize":{"mean":0,"std":1},"buckets":[1],
                "models":{"m@2":{"param_count":1,"test_acc":0.5,
                  "params_sha256":"x",
                  "buckets":{"1":{"file":"f","sha256":"s","bytes":1}}}}}"#,
        )
        .unwrap();
        let err = Manifest::from_value(PathBuf::from("/tmp"), &v).unwrap_err();
        assert!(format!("{err:#}").contains("reserved"), "{err:#}");
    }

    #[test]
    fn sha_mismatch_detected() {
        let dir = std::env::temp_dir().join("flexserve_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m1_b1.hlo.txt"), b"content").unwrap();
        let m = Manifest::from_value(dir.clone(), &fake_manifest_value()).unwrap();
        let a = &m.models[0].buckets[0];
        assert!(m.verify_artifact(a).is_err()); // sha "x" is wrong
    }
}
