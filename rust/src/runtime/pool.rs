//! Executor pool: W device executors, round-robin dispatch — the paper's
//! "scaling horizontally to multiple CPU cores … through the use of
//! Gunicorn workers" (§2.2), with each executor playing one Gunicorn worker
//! that has the full ensemble resident.
//!
//! The pool is also the runtime model-lifecycle authority for the `/v1`
//! control plane: `load_model`/`unload_model` broadcast to every worker
//! (each owns its own PJRT client and executables) and the pool tracks
//! which models are currently resident.

use super::executor::{ExecRequest, ExecResponse, Executor, ExecutorHandle, ExecutorOptions};
use super::manifest::Manifest;
use anyhow::{bail, Result};
use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

pub struct ExecutorPool {
    executors: Vec<Executor>,
    manifest: Arc<Manifest>,
    /// Models currently resident on every worker.
    loaded: RwLock<HashSet<String>>,
    next: AtomicUsize,
}

impl ExecutorPool {
    /// Spawn `workers` executors, each compiling its own copy of the
    /// selected artifacts (compilation is per-client in PJRT).
    pub fn spawn(
        manifest: Arc<Manifest>,
        opts: ExecutorOptions,
        workers: usize,
    ) -> Result<ExecutorPool> {
        assert!(workers > 0);
        let loaded: HashSet<String> = manifest
            .models
            .iter()
            .filter(|m| match &opts.models {
                Some(want) => want.contains(&m.name),
                None => true,
            })
            .map(|m| m.name.clone())
            .collect();
        let executors = (0..workers)
            .map(|_| Executor::spawn(Arc::clone(&manifest), opts.clone()))
            .collect::<Result<Vec<_>>>()?;
        Ok(ExecutorPool {
            executors,
            manifest,
            loaded: RwLock::new(loaded),
            next: AtomicUsize::new(0),
        })
    }

    /// Round-robin pick of a worker handle.
    pub fn handle(&self) -> ExecutorHandle {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.executors.len();
        self.executors[i].handle()
    }

    /// All worker handles (for per-worker dispatch strategies).
    pub fn handles(&self) -> Vec<ExecutorHandle> {
        self.executors.iter().map(|e| e.handle()).collect()
    }

    pub fn workers(&self) -> usize {
        self.executors.len()
    }

    /// Convenience: round-robin blocking inference.
    pub fn infer(&self, req: ExecRequest) -> Result<ExecResponse> {
        self.handle().infer(req)
    }

    /// Is `name` currently resident on the workers?
    pub fn is_loaded(&self, name: &str) -> bool {
        self.loaded.read().unwrap().contains(name)
    }

    /// Currently loaded models, manifest-ordered.
    pub fn loaded_models(&self) -> Vec<String> {
        let loaded = self.loaded.read().unwrap();
        self.manifest
            .models
            .iter()
            .filter(|m| loaded.contains(&m.name))
            .map(|m| m.name.clone())
            .collect()
    }

    /// Compile `name` on every worker (idempotent). `Ok(true)` = at least
    /// one worker newly compiled it. On a mid-broadcast failure, workers
    /// that already compiled the model roll back so the pool stays uniform.
    pub fn load_model(&self, name: &str) -> Result<bool> {
        if self.manifest.model(name).is_none() {
            bail!("unknown model '{name}'");
        }
        let mut newly = false;
        for (i, e) in self.executors.iter().enumerate() {
            match e.handle().load_model(name) {
                Ok(n) => newly |= n,
                Err(err) => {
                    for done in &self.executors[..=i] {
                        let _ = done.handle().unload_model(name);
                    }
                    return Err(err.context(format!("loading '{name}' onto worker {i}")));
                }
            }
        }
        self.loaded.write().unwrap().insert(name.to_string());
        Ok(newly)
    }

    /// Evict `name` from every worker, freeing its device memory.
    /// `Ok(true)` = it was resident somewhere.
    pub fn unload_model(&self, name: &str) -> Result<bool> {
        let mut had = false;
        for e in &self.executors {
            had |= e.handle().unload_model(name)?;
        }
        let tracked = self.loaded.write().unwrap().remove(name);
        Ok(had || tracked)
    }
}

#[cfg(test)]
mod tests {
    // Device-dependent tests live in rust/tests/runtime_integration.rs and
    // rust/tests/server_integration.rs (runtime load/unload lifecycle).
}
