//! Executor pool: W device executors — the paper's "scaling horizontally
//! to multiple CPU cores … through the use of Gunicorn workers" (§2.2),
//! with each executor playing one Gunicorn worker that has the full
//! ensemble resident.
//!
//! Dispatch is **least-loaded**: every worker tracks its in-flight row
//! count and [`ExecutorPool::least_loaded`] picks the emptiest one (ties
//! rotate), so one slow worker no longer backs up every Nth request the
//! way blind round-robin did. Round-robin ([`ExecutorPool::handle`])
//! remains for callers that want deterministic spread.
//!
//! The pool is also the runtime model-lifecycle authority for the `/v1`
//! control plane: `load_model`/`unload_model` broadcast to every worker
//! (each owns its own PJRT client and executables; loads compile on all
//! workers concurrently) and the pool tracks which models are currently
//! resident.

use super::executor::{ExecRequest, ExecResponse, Executor, ExecutorHandle, ExecutorOptions};
use super::manifest::{slot_name, split_slot, Manifest};
use super::supervise::{run_supervisor, SupervisorOptions};
use anyhow::{bail, Result};
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread;

/// Supervision events the pool reports to its observer (the coordinator
/// maps these onto metric counters; the runtime layer stays metrics-free).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolEvent {
    /// A worker slot was found unhealthy and a respawn is being attempted.
    Crash,
    /// A crashed slot was replaced with a fresh executor.
    Respawn,
    /// A respawn attempt failed (will retry after backoff).
    RespawnFailed,
}

pub struct ExecutorPool {
    /// Slots, not bare executors: the supervisor swaps a crashed slot's
    /// executor under its write lock while dispatch reads race past it.
    executors: Vec<RwLock<Executor>>,
    manifest: Arc<Manifest>,
    /// Boot options, kept so respawns compile with the same policy knobs
    /// (the model list is overridden with the *current* resident set).
    base_opts: ExecutorOptions,
    /// Models currently resident on every worker.
    loaded: RwLock<HashSet<String>>,
    next: AtomicUsize,
    crashes: AtomicU64,
    respawns: AtomicU64,
    shutdown: Arc<AtomicBool>,
    supervisor: Mutex<Option<thread::JoinHandle<u64>>>,
}

impl ExecutorPool {
    /// Spawn `workers` executors, each compiling its own copy of the
    /// selected artifacts (compilation is per-client in PJRT).
    pub fn spawn(
        manifest: Arc<Manifest>,
        opts: ExecutorOptions,
        workers: usize,
    ) -> Result<ExecutorPool> {
        assert!(workers > 0);
        let loaded: HashSet<String> = manifest
            .models
            .iter()
            .filter(|m| match &opts.models {
                Some(want) => want.contains(&m.name),
                None => true,
            })
            .map(|m| m.name.clone())
            .collect();
        let executors = (0..workers)
            .map(|_| Executor::spawn(Arc::clone(&manifest), opts.clone()).map(RwLock::new))
            .collect::<Result<Vec<_>>>()?;
        Ok(ExecutorPool {
            executors,
            manifest,
            base_opts: opts,
            loaded: RwLock::new(loaded),
            next: AtomicUsize::new(0),
            crashes: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            shutdown: Arc::new(AtomicBool::new(false)),
            supervisor: Mutex::new(None),
        })
    }

    /// Start the background supervisor: polls worker health and respawns
    /// crashed executors with exponential backoff, reporting [`PoolEvent`]s
    /// to `on_event`. Holds only a `Weak` reference, so the pool can still
    /// drop; `Drop` joins the thread. Call at most once.
    pub fn start_supervisor(
        self: &Arc<Self>,
        opts: SupervisorOptions,
        on_event: impl Fn(PoolEvent) + Send + Sync + 'static,
    ) {
        let weak = Arc::downgrade(self);
        let weak2 = Arc::downgrade(self);
        let shutdown = Arc::clone(&self.shutdown);
        let n = self.executors.len();
        let handle = thread::Builder::new()
            .name("flexserve-supervisor".into())
            .spawn(move || {
                run_supervisor(
                    opts,
                    &shutdown,
                    n,
                    move |i| match weak.upgrade() {
                        // Report "healthy" once the pool is gone so the
                        // loop idles until the shutdown flag (also owned
                        // by the dropped pool's clone) stops it.
                        None => true,
                        Some(p) => p.executors[i].read().unwrap().is_healthy(),
                    },
                    move |i| {
                        let Some(p) = weak2.upgrade() else {
                            return Ok(());
                        };
                        p.crashes.fetch_add(1, Ordering::Relaxed);
                        on_event(PoolEvent::Crash);
                        match p.respawn_slot(i) {
                            Ok(()) => {
                                on_event(PoolEvent::Respawn);
                                Ok(())
                            }
                            Err(e) => {
                                on_event(PoolEvent::RespawnFailed);
                                Err(e)
                            }
                        }
                    },
                )
            })
            .expect("spawning pool supervisor thread");
        *self.supervisor.lock().unwrap() = Some(handle);
    }

    /// Replace slot `i`'s crashed executor with a fresh one compiled with
    /// the boot policy but the *current* resident model set, so runtime
    /// loads/unloads survive the crash.
    fn respawn_slot(&self, i: usize) -> Result<()> {
        let models: Vec<String> = self.loaded.read().unwrap().iter().cloned().collect();
        let opts = ExecutorOptions {
            models: Some(models),
            ..self.base_opts.clone()
        };
        let fresh = Executor::spawn(Arc::clone(&self.manifest), opts)?;
        // Old executor drops here: its device thread already exited, so
        // the Shutdown send fails harmlessly and join returns at once.
        *self.executors[i].write().unwrap() = fresh;
        self.respawns.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Crash incidents detected by the supervisor so far.
    pub fn crashes(&self) -> u64 {
        self.crashes.load(Ordering::Relaxed)
    }

    /// Successful respawns so far.
    pub fn respawns(&self) -> u64 {
        self.respawns.load(Ordering::Relaxed)
    }

    /// Per-worker health flags (true = device thread alive).
    pub fn healthy_workers(&self) -> Vec<bool> {
        self.executors
            .iter()
            .map(|e| e.read().unwrap().is_healthy())
            .collect()
    }

    /// Round-robin pick of a worker handle, skipping crashed workers when
    /// a healthy one exists.
    pub fn handle(&self) -> ExecutorHandle {
        let n = self.executors.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed) % n;
        for off in 0..n {
            let e = self.executors[(start + off) % n].read().unwrap();
            if e.is_healthy() {
                return e.handle();
            }
        }
        // Every worker crashed: fail fast through the dead handle's typed
        // error rather than stalling the caller.
        self.executors[start].read().unwrap().handle()
    }

    /// Pick the healthy worker with the fewest in-flight rows (ties rotate
    /// via the round-robin cursor so an idle pool still spreads work);
    /// crashed workers are skipped until the supervisor respawns them.
    pub fn least_loaded(&self) -> ExecutorHandle {
        let mut loads = Vec::with_capacity(self.executors.len());
        let mut healthy = Vec::with_capacity(self.executors.len());
        for e in &self.executors {
            let e = e.read().unwrap();
            loads.push(e.in_flight_rows());
            healthy.push(e.is_healthy());
        }
        let start = self.next.fetch_add(1, Ordering::Relaxed) % self.executors.len();
        let pick = pick_least_loaded_healthy(&loads, &healthy, start);
        self.executors[pick].read().unwrap().handle()
    }

    /// Per-worker in-flight row counts (diagnostics / tests).
    pub fn in_flight_rows(&self) -> Vec<usize> {
        self.executors
            .iter()
            .map(|e| e.read().unwrap().in_flight_rows())
            .collect()
    }

    /// All worker handles (for per-worker dispatch strategies).
    pub fn handles(&self) -> Vec<ExecutorHandle> {
        self.executors
            .iter()
            .map(|e| e.read().unwrap().handle())
            .collect()
    }

    pub fn workers(&self) -> usize {
        self.executors.len()
    }

    /// Convenience: round-robin blocking inference.
    pub fn infer(&self, req: ExecRequest) -> Result<ExecResponse> {
        self.handle().infer(req)
    }

    /// Is `name` currently resident on the workers?
    pub fn is_loaded(&self, name: &str) -> bool {
        self.loaded.read().unwrap().contains(name)
    }

    /// Currently loaded models, manifest-ordered.
    pub fn loaded_models(&self) -> Vec<String> {
        let loaded = self.loaded.read().unwrap();
        self.manifest
            .models
            .iter()
            .filter(|m| loaded.contains(&m.name))
            .map(|m| m.name.clone())
            .collect()
    }

    /// Compile `name` on every worker (idempotent). `Ok(true)` = at least
    /// one worker newly compiled it. The broadcast is concurrent — every
    /// worker compiles at once, so a runtime load costs one compile of
    /// wall-clock instead of W (boot-parity). On any failure the workers
    /// that did compile roll back so the pool stays uniform.
    pub fn load_model(&self, name: &str) -> Result<bool> {
        if self.manifest.model(name).is_none() {
            bail!("unknown model '{name}'");
        }
        // Fan the Load message out to every device thread first…
        let receivers = self
            .executors
            .iter()
            .map(|e| e.read().unwrap().handle().load_model_async(name))
            .collect::<Result<Vec<_>>>()?;
        // …then collect ALL outcomes (never bail mid-collect: rollback
        // must wait until every worker has finished compiling or failing).
        let mut newly = false;
        let mut failure: Option<(usize, anyhow::Error)> = None;
        for (i, rx) in receivers.into_iter().enumerate() {
            match rx.recv() {
                Ok(Ok(n)) => newly |= n,
                Ok(Err(err)) => failure = failure.or(Some((i, err))),
                Err(_) => {
                    failure =
                        failure.or(Some((i, anyhow::anyhow!("executor dropped the load request"))))
                }
            }
        }
        if let Some((i, err)) = failure {
            for e in &self.executors {
                let _ = e.read().unwrap().handle().unload_model(name);
            }
            return Err(err.context(format!("loading '{name}' onto worker {i}")));
        }
        self.loaded.write().unwrap().insert(name.to_string());
        Ok(newly)
    }

    /// Evict `name` from every worker, freeing its device memory.
    /// `Ok(true)` = it was resident somewhere.
    pub fn unload_model(&self, name: &str) -> Result<bool> {
        let mut had = false;
        for e in &self.executors {
            had |= e.read().unwrap().handle().unload_model(name)?;
        }
        let tracked = self.loaded.write().unwrap().remove(name);
        Ok(had || tracked)
    }

    // ---- version-aware lifecycle (registry slots) ------------------------
    // Pool keys carry a version dimension as slots ("m" = v1, "m@2" = v2):
    // the same Msg::Load/Unload broadcast — with its concurrent compile and
    // rollback-on-any-failure semantics — moves one (model, version) at a
    // time, so multiple versions of a model stay resident concurrently.

    /// Compile one (model, version) onto every worker (idempotent).
    pub fn load_version(&self, name: &str, version: u32) -> Result<bool> {
        self.load_model(&slot_name(name, version))
    }

    /// Evict one (model, version) from every worker.
    pub fn unload_version(&self, name: &str, version: u32) -> Result<bool> {
        self.unload_model(&slot_name(name, version))
    }

    /// Is this exact (model, version) resident on the workers?
    pub fn is_version_loaded(&self, name: &str, version: u32) -> bool {
        self.is_loaded(&slot_name(name, version))
    }

    /// Currently-loaded versions of one model, ascending.
    pub fn loaded_versions(&self, name: &str) -> Vec<u32> {
        let loaded = self.loaded.read().unwrap();
        let mut versions: Vec<u32> = loaded
            .iter()
            .filter_map(|slot| {
                let (bare, v) = split_slot(slot);
                (bare == name).then_some(v)
            })
            .collect();
        versions.sort_unstable();
        versions
    }

    /// Is ANY version of `name` resident? (The bare-model lifecycle and
    /// readiness views care about servability, not a specific version.)
    pub fn any_version_loaded(&self, name: &str) -> bool {
        self.loaded
            .read()
            .unwrap()
            .iter()
            .any(|slot| split_slot(slot).0 == name)
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.supervisor.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

/// Health-masked least-loaded selection: the healthy index with the
/// minimum load (ties rotate from `start`); if *every* worker is crashed,
/// fall back to the plain pick so the caller fails fast on a typed error
/// instead of having nowhere to send.
pub fn pick_least_loaded_healthy(loads: &[usize], healthy: &[bool], start: usize) -> usize {
    debug_assert_eq!(loads.len(), healthy.len());
    let n = loads.len();
    let mut best: Option<usize> = None;
    for off in 0..n {
        let i = (start + off) % n;
        if !healthy[i] {
            continue;
        }
        match best {
            None => best = Some(i),
            Some(b) if loads[i] < loads[b] => best = Some(i),
            _ => {}
        }
    }
    best.unwrap_or_else(|| pick_least_loaded(loads, start))
}

/// Pure least-loaded selection: the index with the minimum load, scanning
/// from `start` so equal loads rotate instead of pinning worker 0.
pub fn pick_least_loaded(loads: &[usize], start: usize) -> usize {
    debug_assert!(!loads.is_empty());
    let n = loads.len();
    let mut best = start % n;
    for off in 1..n {
        let i = (start + off) % n;
        if loads[i] < loads[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    // Device-dependent tests live in rust/tests/runtime_integration.rs and
    // rust/tests/server_integration.rs (runtime load/unload lifecycle +
    // parallel-broadcast rollback); the selection rule is pure:
    use super::*;

    #[test]
    fn least_loaded_picks_minimum() {
        assert_eq!(pick_least_loaded(&[5, 0, 3], 0), 1);
        assert_eq!(pick_least_loaded(&[0, 0, 7], 2), 0); // skips the busy one
        assert_eq!(pick_least_loaded(&[9], 4), 0);
    }

    #[test]
    fn ties_rotate_with_start() {
        // All-equal loads: the pick follows the rotating cursor.
        assert_eq!(pick_least_loaded(&[2, 2, 2], 0), 0);
        assert_eq!(pick_least_loaded(&[2, 2, 2], 1), 1);
        assert_eq!(pick_least_loaded(&[2, 2, 2], 5), 2);
    }

    #[test]
    fn one_slow_worker_never_wins() {
        // The round-robin failure mode this replaces: worker 1 is stuck
        // with a deep backlog, yet round-robin would still hand it every
        // Nth request. Least-loaded never does.
        for start in 0..8 {
            assert_ne!(pick_least_loaded(&[0, 1000, 0, 0], start), 1);
        }
    }

    #[test]
    fn crashed_workers_are_skipped() {
        // Worker 0 is idle but crashed: the healthy-but-busier worker wins.
        for start in 0..8 {
            assert_eq!(
                pick_least_loaded_healthy(&[0, 7, 9], &[false, true, true], start),
                1
            );
        }
        // Masked ties still rotate with the cursor.
        assert_eq!(
            pick_least_loaded_healthy(&[0, 2, 2], &[false, true, true], 2),
            2
        );
        assert_eq!(
            pick_least_loaded_healthy(&[0, 2, 2], &[false, true, true], 1),
            1
        );
    }

    #[test]
    fn all_crashed_falls_back_to_plain_pick() {
        // Nowhere healthy to send: degrade to the unmasked pick so the
        // caller gets a fast typed WorkerCrashed instead of a panic here.
        assert_eq!(
            pick_least_loaded_healthy(&[3, 1, 2], &[false, false, false], 0),
            1
        );
    }
}
