//! Executor pool: W device executors, round-robin dispatch — the paper's
//! "scaling horizontally to multiple CPU cores … through the use of
//! Gunicorn workers" (§2.2), with each executor playing one Gunicorn worker
//! that has the full ensemble resident.

use super::executor::{ExecRequest, ExecResponse, Executor, ExecutorHandle, ExecutorOptions};
use super::manifest::Manifest;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

pub struct ExecutorPool {
    executors: Vec<Executor>,
    next: AtomicUsize,
}

impl ExecutorPool {
    /// Spawn `workers` executors, each compiling its own copy of the
    /// selected artifacts (compilation is per-client in PJRT).
    pub fn spawn(
        manifest: Arc<Manifest>,
        opts: ExecutorOptions,
        workers: usize,
    ) -> Result<ExecutorPool> {
        assert!(workers > 0);
        let executors = (0..workers)
            .map(|_| Executor::spawn(Arc::clone(&manifest), opts.clone()))
            .collect::<Result<Vec<_>>>()?;
        Ok(ExecutorPool {
            executors,
            next: AtomicUsize::new(0),
        })
    }

    /// Round-robin pick of a worker handle.
    pub fn handle(&self) -> ExecutorHandle {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.executors.len();
        self.executors[i].handle()
    }

    /// All worker handles (for per-worker dispatch strategies).
    pub fn handles(&self) -> Vec<ExecutorHandle> {
        self.executors.iter().map(|e| e.handle()).collect()
    }

    pub fn workers(&self) -> usize {
        self.executors.len()
    }

    /// Convenience: round-robin blocking inference.
    pub fn infer(&self, req: ExecRequest) -> Result<ExecResponse> {
        self.handle().infer(req)
    }
}

#[cfg(test)]
mod tests {
    // Device-dependent tests live in rust/tests/runtime_integration.rs.
}
