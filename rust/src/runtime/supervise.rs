//! Generic slot supervision: a polling loop that watches N worker slots'
//! health flags and respawns crashed ones with exponential backoff.
//!
//! The loop is deliberately abstract — `healthy(i)` and `respawn(i)` are
//! closures — so [`pool::ExecutorPool`](super::pool::ExecutorPool) drives
//! it over real device executors while the device-free `chaos-smoke`
//! harness drives the *same* machinery over toy crashing workers and
//! still exercises the respawn counters end to end.
//!
//! Policy: an unhealthy slot is respawned as soon as its backoff window
//! allows; every attempt (success or failure) widens the window
//! (base·2ᵏ, capped), and the window resets only after the slot has
//! stayed healthy for `heal_after` — a crash-looping worker therefore
//! backs off instead of hot-spinning device setup.

use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::thread;
use std::time::{Duration, Instant};

/// Exponential backoff: `base * 2^attempts`, capped at `max`.
#[derive(Debug)]
pub struct Backoff {
    base: Duration,
    max: Duration,
    attempts: u32,
}

impl Backoff {
    pub fn new(base: Duration, max: Duration) -> Backoff {
        Backoff {
            base,
            max,
            attempts: 0,
        }
    }

    /// The delay to wait before the *next* attempt; widens each call.
    pub fn next_delay(&mut self) -> Duration {
        let factor = 1u32.checked_shl(self.attempts).unwrap_or(u32::MAX);
        let delay = self
            .base
            .checked_mul(factor)
            .map_or(self.max, |d| d.min(self.max));
        self.attempts = self.attempts.saturating_add(1);
        delay
    }

    /// Back to the base window (the worker proved itself healthy).
    pub fn reset(&mut self) {
        self.attempts = 0;
    }
}

#[derive(Debug, Clone, Copy)]
pub struct SupervisorOptions {
    /// Health-poll cadence.
    pub poll: Duration,
    /// First-respawn backoff window.
    pub backoff_base: Duration,
    /// Backoff cap.
    pub backoff_max: Duration,
    /// Continuous healthy time after which a slot's backoff resets.
    pub heal_after: Duration,
}

impl Default for SupervisorOptions {
    fn default() -> SupervisorOptions {
        SupervisorOptions {
            poll: Duration::from_millis(100),
            backoff_base: Duration::from_millis(500),
            backoff_max: Duration::from_secs(30),
            heal_after: Duration::from_secs(10),
        }
    }
}

/// Poll `n` slots until `shutdown`; respawn unhealthy ones per the backoff
/// policy above. Returns the number of successful respawns (failed
/// `respawn` attempts are retried on the next eligible poll).
pub fn run_supervisor(
    opts: SupervisorOptions,
    shutdown: &AtomicBool,
    n: usize,
    healthy: impl Fn(usize) -> bool,
    mut respawn: impl FnMut(usize) -> Result<()>,
) -> u64 {
    let mut backoffs: Vec<Backoff> = (0..n)
        .map(|_| Backoff::new(opts.backoff_base, opts.backoff_max))
        .collect();
    let mut not_before: Vec<Option<Instant>> = vec![None; n];
    let mut healthy_since: Vec<Option<Instant>> = vec![None; n];
    let mut respawned = 0u64;
    while !shutdown.load(Ordering::Relaxed) {
        let now = Instant::now();
        for i in 0..n {
            if healthy(i) {
                match healthy_since[i] {
                    Some(since) if now.duration_since(since) >= opts.heal_after => {
                        backoffs[i].reset();
                    }
                    Some(_) => {}
                    None => healthy_since[i] = Some(now),
                }
                continue;
            }
            healthy_since[i] = None;
            if let Some(t) = not_before[i] {
                if now < t {
                    continue; // still inside the backoff window
                }
            }
            not_before[i] = Some(now + backoffs[i].next_delay());
            if respawn(i).is_ok() {
                respawned += 1;
            }
        }
        thread::sleep(opts.poll);
    }
    respawned
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::{Arc, Mutex};

    #[test]
    fn backoff_doubles_and_caps_then_resets() {
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_millis(40));
        let delays: Vec<u64> = (0..5).map(|_| b.next_delay().as_millis() as u64).collect();
        assert_eq!(delays, vec![10, 20, 40, 40, 40]);
        b.reset();
        assert_eq!(b.next_delay(), Duration::from_millis(10));
    }

    #[test]
    fn backoff_survives_huge_attempt_counts() {
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_secs(30));
        b.attempts = 200; // would overflow a shift without the guards
        assert_eq!(b.next_delay(), Duration::from_secs(30));
    }

    #[test]
    fn supervisor_respawns_crashed_slot_and_stops_on_shutdown() {
        let opts = SupervisorOptions {
            poll: Duration::from_millis(2),
            backoff_base: Duration::from_millis(1),
            backoff_max: Duration::from_millis(8),
            heal_after: Duration::from_millis(50),
        };
        let flags: Arc<Vec<AtomicBool>> =
            Arc::new((0..2).map(|_| AtomicBool::new(true)).collect());
        let shutdown = Arc::new(AtomicBool::new(false));
        let respawns = Arc::new(AtomicU64::new(0));
        let t = {
            let flags = Arc::clone(&flags);
            let shutdown = Arc::clone(&shutdown);
            let respawns = Arc::clone(&respawns);
            thread::spawn(move || {
                run_supervisor(
                    opts,
                    &shutdown,
                    2,
                    |i| flags[i].load(Ordering::Relaxed),
                    |i| {
                        respawns.fetch_add(1, Ordering::Relaxed);
                        flags[i].store(true, Ordering::Relaxed);
                        Ok(())
                    },
                )
            })
        };
        flags[1].store(false, Ordering::Relaxed);
        let deadline = Instant::now() + Duration::from_secs(5);
        while !flags[1].load(Ordering::Relaxed) && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(2));
        }
        shutdown.store(true, Ordering::Relaxed);
        let total = t.join().unwrap();
        assert!(flags[1].load(Ordering::Relaxed), "slot 1 was respawned");
        assert_eq!(total, respawns.load(Ordering::Relaxed));
        assert!(total >= 1, "at least the crashed slot respawned");
    }

    #[test]
    fn failing_respawns_back_off() {
        let opts = SupervisorOptions {
            poll: Duration::from_millis(1),
            backoff_base: Duration::from_millis(30),
            backoff_max: Duration::from_millis(120),
            heal_after: Duration::from_secs(10),
        };
        let shutdown = Arc::new(AtomicBool::new(false));
        let attempts: Arc<Mutex<Vec<Instant>>> = Arc::new(Mutex::new(Vec::new()));
        let t = {
            let shutdown = Arc::clone(&shutdown);
            let attempts = Arc::clone(&attempts);
            thread::spawn(move || {
                run_supervisor(
                    opts,
                    &shutdown,
                    1,
                    |_| false, // never heals
                    |_| {
                        attempts.lock().unwrap().push(Instant::now());
                        anyhow::bail!("still broken")
                    },
                )
            })
        };
        thread::sleep(Duration::from_millis(120));
        shutdown.store(true, Ordering::Relaxed);
        assert_eq!(t.join().unwrap(), 0, "failed respawns are not counted");
        let ts = attempts.lock().unwrap().clone();
        assert!(ts.len() >= 2, "kept retrying: {} attempts", ts.len());
        // Windows widen: the second gap is at least the base window.
        if ts.len() >= 3 {
            assert!(ts[2].duration_since(ts[1]) >= Duration::from_millis(30));
        }
        assert!(ts[1].duration_since(ts[0]) >= Duration::from_millis(30));
    }
}
