//! Server configuration: JSON config file + CLI-style overrides (clap is
//! unavailable offline; the flag parser lives here and serves `main.rs`).

use crate::coordinator::{BreakerConfig, SchedConfig};
use crate::json::{self, Value};
use crate::registry::RegistryConfig;
use anyhow::{anyhow, bail, Context, Result};
use std::path::PathBuf;
use std::time::Duration;

/// Full serving configuration (defaults match `flexserve serve` docs).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address, e.g. "127.0.0.1:8080" (port 0 = ephemeral).
    pub addr: String,
    /// HTTP connection worker threads (Gunicorn-worker analogue).
    pub http_workers: usize,
    /// Device executor threads, each owning a full PJRT client + ensemble.
    pub device_workers: usize,
    /// Artifact directory (produced by `make artifacts`).
    pub artifacts: PathBuf,
    /// Verify every artifact SHA-256 against the manifest at startup.
    pub verify_sha: bool,
    /// Run a warmup forward per executable at startup.
    pub warmup: bool,
    /// Restrict the served model set (None = all models in the manifest).
    pub models: Option<Vec<String>>,
    /// The scheduling plane: per-target flexible batching, adaptive
    /// windows, admission control, deadlines (None = pass-through, the
    /// paper's base behaviour).
    pub scheduler: Option<SchedConfig>,
    /// The model registry: durable audit trail + auto-rollback guardrail
    /// defaults (`registry` JSON block; `--audit-log`, `--guardrail-*`).
    pub registry: RegistryConfig,
    /// Per-model-bucket circuit breakers (`breaker` JSON block;
    /// `--breaker-fail-threshold`, `--breaker-cooldown-ms`).
    pub breaker: BreakerConfig,
    /// Seeded fault-injection spec, e.g.
    /// `"exec.device=0.2:panic,sched.flush=0.1:error"` (None = chaos off;
    /// disabled sites cost one atomic load).
    pub chaos: Option<String>,
    /// Seed for the chaos plane's per-site PRNGs (same spec + same seed =
    /// same injection sequence).
    pub chaos_seed: u64,
    /// Emit one access-log line per request on stderr (router middleware).
    pub access_log: bool,
    /// Reap keep-alive connections idle (byte-silent between requests)
    /// longer than this, in ms (0 = off). Mux/event connections are
    /// exempt — they keep themselves alive with ping/pong frames.
    pub idle_timeout_ms: u64,
    /// Per-mux-connection concurrent in-flight request cap (`mux` block;
    /// `--mux-max-inflight`). Past it, `request` frames shed with the
    /// `429 server.overloaded` envelope.
    pub mux_max_inflight: usize,
    /// Mux responses larger than this stream as bounded `chunk` frames
    /// (`--mux-chunk-bytes`; 0 = never chunk).
    pub mux_chunk_bytes: usize,
    /// Per-subscriber event queue bound for `/v1/events` and mux
    /// subscriptions (`events` block; `--events-buffer`).
    pub events_buffer: usize,
    /// Period between metrics-snapshot publishes onto the event bus's
    /// `metrics` topic, in ms (`--events-metrics-ms`; 0 = off).
    pub events_metrics_ms: u64,
    /// Default execution backend for every model (`backend.default`;
    /// `--backend xla|cpu|quant`). None/"auto" defers to each manifest
    /// entry's own `backend` field, with XLA as the final fallback.
    pub backend: Option<String>,
    /// Per-model backend overrides (`backend.models` JSON map). Each pair
    /// is `(model, backend)`; an override outranks the manifest but not
    /// the global `--backend` pin.
    pub backend_overrides: Vec<(String, String)>,
    /// Intra-op worker threads for the CPU/quant backends
    /// (`backend.cpu_workers`; `--cpu-workers`; 0 = auto-size to
    /// physical cores).
    pub cpu_workers: usize,
    /// Buffer-arena retention cap per device worker, in MB
    /// (`backend.arena_cap_mb`; `--arena-cap-mb`; 0 = 64 MB default).
    pub arena_cap_mb: usize,
    /// Per-topic event-bus subscriber cap
    /// (`events.max_subscribers_per_topic`; `--events-max-subscribers`;
    /// 0 = unlimited). Past it, new subscriptions shed with the typed
    /// `429 events.subscriber_limit` envelope.
    pub events_max_subscribers_per_topic: usize,
    /// Tenant specs for the multi-tenant serving plane (`tenants` JSON
    /// array; `--tenants-file`). Empty = open mode: every request runs
    /// as the implicit `anonymous` tenant with no auth, quota, or
    /// fairness split.
    pub tenants: Vec<crate::tenant::TenantSpec>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8080".into(),
            http_workers: 8,
            device_workers: 1, // one shared device, per the paper
            artifacts: crate::runtime::manifest::default_artifact_dir(),
            verify_sha: true,
            warmup: true,
            models: None,
            scheduler: Some(SchedConfig::default()),
            registry: RegistryConfig::default(),
            breaker: BreakerConfig::default(),
            chaos: None,
            chaos_seed: 0,
            access_log: false,
            idle_timeout_ms: 0,
            mux_max_inflight: 32,
            mux_chunk_bytes: 64 << 10,
            events_buffer: 256,
            events_metrics_ms: 5000,
            backend: None,
            backend_overrides: Vec::new(),
            cpu_workers: 0,
            arena_cap_mb: 0,
            events_max_subscribers_per_topic: 0,
            tenants: Vec::new(),
        }
    }
}

impl ServeConfig {
    /// Load from a JSON config file.
    pub fn from_file(path: &str) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let v = json::parse(&text).with_context(|| format!("parsing {path}"))?;
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&v)?;
        Ok(cfg)
    }

    fn apply_json(&mut self, v: &Value) -> Result<()> {
        for (key, val) in v.as_obj().ok_or_else(|| anyhow!("config must be an object"))? {
            self.set(key, val)?;
        }
        Ok(())
    }

    fn set(&mut self, key: &str, val: &Value) -> Result<()> {
        match key {
            "addr" => self.addr = req_str(key, val)?.to_string(),
            "http_workers" => self.http_workers = req_usize(key, val)?.max(1),
            "device_workers" => self.device_workers = req_usize(key, val)?.max(1),
            "artifacts" => self.artifacts = PathBuf::from(req_str(key, val)?),
            "verify_sha" => self.verify_sha = req_bool(key, val)?,
            "warmup" => self.warmup = req_bool(key, val)?,
            "access_log" => self.access_log = req_bool(key, val)?,
            "models" => {
                let arr = val
                    .as_arr()
                    .ok_or_else(|| anyhow!("'models' must be an array"))?;
                let names = arr
                    .iter()
                    .map(|m| {
                        m.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| anyhow!("'models' entries must be strings"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                self.models = if names.is_empty() { None } else { Some(names) };
            }
            // "batcher" is the legacy spelling of the scheduler block (it
            // only ever carried the batching knobs).
            "scheduler" | "batcher" => match val {
                Value::Null | Value::Bool(false) => self.scheduler = None,
                Value::Bool(true) => self.scheduler = Some(SchedConfig::default()),
                Value::Obj(_) => {
                    let mut cfg = self.scheduler.unwrap_or_default();
                    if let Some(mb) = val.get("max_batch") {
                        cfg.max_batch = mb
                            .as_usize()
                            .ok_or_else(|| anyhow!("{key}.max_batch must be an integer"))?
                            .max(1);
                    }
                    if let Some(d) = val.get("max_delay_us") {
                        cfg.max_delay = Duration::from_micros(
                            d.as_u64()
                                .ok_or_else(|| anyhow!("{key}.max_delay_us must be an integer"))?,
                        );
                    }
                    if let Some(c) = val.get("queue_cap") {
                        cfg.queue_cap = c
                            .as_usize()
                            .ok_or_else(|| anyhow!("{key}.queue_cap must be an integer (0 = unbounded)"))?;
                    }
                    if let Some(d) = val.get("deadline_ms") {
                        let ms = d
                            .as_u64()
                            .ok_or_else(|| anyhow!("{key}.deadline_ms must be an integer (0 = none)"))?;
                        cfg.deadline = (ms > 0).then(|| Duration::from_millis(ms));
                    }
                    if let Some(a) = val.get("adaptive") {
                        cfg.adaptive = a
                            .as_bool()
                            .ok_or_else(|| anyhow!("{key}.adaptive must be a bool"))?;
                    }
                    if let Some(d) = val.get("drain_timeout_ms") {
                        let ms = d.as_u64().ok_or_else(|| {
                            anyhow!("{key}.drain_timeout_ms must be an integer (0 = wait forever)")
                        })?;
                        cfg.drain_timeout = (ms > 0).then(|| Duration::from_millis(ms));
                    }
                    self.scheduler = Some(cfg);
                }
                _ => bail!("'{key}' must be bool, null, or object"),
            },
            "registry" => {
                if val.as_obj().is_none() {
                    bail!("'registry' must be an object");
                }
                if let Some(p) = val.get("audit_log") {
                    self.registry.audit_log = match p {
                        Value::Null => None,
                        _ => Some(PathBuf::from(req_str("registry.audit_log", p)?)),
                    };
                }
                if let Some(r) = val.get("max_error_rate") {
                    let rate = r
                        .as_f64()
                        .filter(|r| (0.0..=1.0).contains(r))
                        .ok_or_else(|| anyhow!("registry.max_error_rate must be in 0..=1"))?;
                    self.registry.guardrails.max_error_rate = rate;
                }
                if let Some(p) = val.get("max_p95_ms") {
                    self.registry.guardrails.max_p95_us = p
                        .as_u64()
                        .ok_or_else(|| anyhow!("registry.max_p95_ms must be an integer (0 = off)"))?
                        * 1000;
                }
                if let Some(s) = val.get("min_samples") {
                    self.registry.guardrails.min_samples = s
                        .as_usize()
                        .filter(|&s| s >= 1)
                        .ok_or_else(|| anyhow!("registry.min_samples must be >= 1"))?;
                }
            }
            "breaker" => {
                if val.as_obj().is_none() {
                    bail!("'breaker' must be an object");
                }
                if let Some(t) = val.get("fail_threshold") {
                    self.breaker.fail_threshold = t
                        .as_usize()
                        .filter(|&t| t >= 1)
                        .ok_or_else(|| anyhow!("breaker.fail_threshold must be >= 1"))?
                        as u32;
                }
                if let Some(ms) = val.get("cooldown_ms") {
                    self.breaker.cooldown = Duration::from_millis(
                        ms.as_u64()
                            .filter(|&ms| ms >= 1)
                            .ok_or_else(|| anyhow!("breaker.cooldown_ms must be >= 1"))?,
                    );
                }
            }
            "chaos" => {
                self.chaos = match val {
                    Value::Null => None,
                    _ => Some(req_str(key, val)?.to_string()),
                };
            }
            "chaos_seed" => {
                self.chaos_seed = val
                    .as_u64()
                    .ok_or_else(|| anyhow!("'chaos_seed' must be an integer"))?;
            }
            "idle_timeout_ms" => {
                self.idle_timeout_ms = val
                    .as_u64()
                    .ok_or_else(|| anyhow!("'idle_timeout_ms' must be an integer (0 = off)"))?;
            }
            "mux" => {
                if val.as_obj().is_none() {
                    bail!("'mux' must be an object");
                }
                if let Some(m) = val.get("max_inflight") {
                    self.mux_max_inflight = m
                        .as_usize()
                        .filter(|&m| m >= 1)
                        .ok_or_else(|| anyhow!("mux.max_inflight must be >= 1"))?;
                }
                if let Some(b) = val.get("chunk_bytes") {
                    self.mux_chunk_bytes = b
                        .as_usize()
                        .ok_or_else(|| anyhow!("mux.chunk_bytes must be an integer (0 = never chunk)"))?;
                }
            }
            "events" => {
                if val.as_obj().is_none() {
                    bail!("'events' must be an object");
                }
                if let Some(b) = val.get("buffer") {
                    self.events_buffer = b
                        .as_usize()
                        .filter(|&b| b >= 1)
                        .ok_or_else(|| anyhow!("events.buffer must be >= 1"))?;
                }
                if let Some(ms) = val.get("metrics_interval_ms") {
                    self.events_metrics_ms = ms
                        .as_u64()
                        .ok_or_else(|| anyhow!("events.metrics_interval_ms must be an integer (0 = off)"))?;
                }
                if let Some(m) = val.get("max_subscribers_per_topic") {
                    self.events_max_subscribers_per_topic = m.as_usize().ok_or_else(|| {
                        anyhow!("events.max_subscribers_per_topic must be an integer (0 = unlimited)")
                    })?;
                }
            }
            "tenants" => {
                self.tenants = match val {
                    Value::Null => Vec::new(),
                    _ => crate::tenant::parse_tenants(val).map_err(|e| anyhow!("tenants: {e}"))?,
                };
            }
            "backend" => match val {
                Value::Null => {
                    self.backend = None;
                    self.backend_overrides.clear();
                }
                // Shorthand: `"backend": "cpu"` pins the default only.
                Value::Str(s) => self.backend = parse_backend_name("backend", s)?,
                Value::Obj(_) => {
                    if let Some(d) = val.get("default") {
                        self.backend = match d {
                            Value::Null => None,
                            _ => parse_backend_name("backend.default", req_str("backend.default", d)?)?,
                        };
                    }
                    if let Some(m) = val.get("models") {
                        let obj = m
                            .as_obj()
                            .ok_or_else(|| anyhow!("'backend.models' must be an object"))?;
                        self.backend_overrides = obj
                            .iter()
                            .map(|(model, b)| {
                                let name = req_str("backend.models entry", b)?;
                                parse_backend_name("backend.models entry", name)?
                                    .ok_or_else(|| {
                                        anyhow!("backend.models['{model}'] must name a backend, not 'auto'")
                                    })
                                    .map(|b| (model.clone(), b))
                            })
                            .collect::<Result<Vec<_>>>()?;
                    }
                    if let Some(w) = val.get("cpu_workers") {
                        self.cpu_workers = w
                            .as_usize()
                            .ok_or_else(|| anyhow!("backend.cpu_workers must be an integer (0 = auto)"))?;
                    }
                    if let Some(a) = val.get("arena_cap_mb") {
                        self.arena_cap_mb = a
                            .as_usize()
                            .ok_or_else(|| anyhow!("backend.arena_cap_mb must be an integer (0 = default)"))?;
                    }
                }
                _ => bail!("'backend' must be a string, null, or object"),
            },
            // A combined cluster config file may carry a `gateway` block
            // (consumed by `GatewayConfig::from_file`); the serve side
            // validates the shape and otherwise ignores it.
            "gateway" => {
                if val.as_obj().is_none() {
                    bail!("'gateway' must be an object");
                }
            }
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Apply `--key value` / `--key=value` CLI overrides. Recognized keys
    /// mirror the JSON config (`--addr`, `--http-workers`,
    /// `--device-workers`, `--artifacts`, `--models a,b`, `--no-batcher`,
    /// `--batch-delay-us N`, `--max-batch N`, `--queue-cap N`,
    /// `--deadline-ms N`, `--drain-timeout-ms N`, `--adaptive-window
    /// on|off`, `--no-verify`, `--no-warmup`, `--access-log`,
    /// `--breaker-fail-threshold N`, `--breaker-cooldown-ms N`,
    /// `--chaos SPEC`, `--chaos-seed N`, `--idle-timeout-ms N`,
    /// `--mux-max-inflight N`, `--mux-chunk-bytes N`, `--events-buffer N`,
    /// `--events-metrics-ms N`, `--events-max-subscribers N`,
    /// `--tenants-file PATH`, `--backend xla|cpu|quant|auto`,
    /// `--backend-override model=kind[,model=kind]`, `--cpu-workers N`,
    /// `--arena-cap-mb N`).
    pub fn apply_cli(&mut self, args: &[String]) -> Result<()> {
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            let (flag, inline) = match arg.split_once('=') {
                Some((f, v)) => (f.to_string(), Some(v.to_string())),
                None => (arg.clone(), None),
            };
            let mut take = || -> Result<String> {
                inline.clone().or_else(|| it.next().cloned()).ok_or_else(|| {
                    anyhow!("flag {flag} requires a value")
                })
            };
            match flag.as_str() {
                "--addr" => self.addr = take()?,
                "--http-workers" => self.http_workers = take()?.parse::<usize>()?.max(1),
                "--device-workers" => self.device_workers = take()?.parse::<usize>()?.max(1),
                "--artifacts" => self.artifacts = PathBuf::from(take()?),
                "--models" => {
                    self.models = Some(
                        take()?
                            .split(',')
                            .filter(|s| !s.is_empty())
                            .map(str::to_string)
                            .collect(),
                    )
                }
                "--no-batcher" | "--no-scheduler" => self.scheduler = None,
                "--max-batch" => {
                    let v = take()?.parse::<usize>()?.max(1);
                    self.scheduler.get_or_insert_with(Default::default).max_batch = v;
                }
                "--batch-delay-us" => {
                    let v = Duration::from_micros(take()?.parse()?);
                    self.scheduler.get_or_insert_with(Default::default).max_delay = v;
                }
                "--queue-cap" => {
                    let v = take()?.parse::<usize>()?;
                    self.scheduler.get_or_insert_with(Default::default).queue_cap = v;
                }
                "--deadline-ms" => {
                    let ms = take()?.parse::<u64>()?;
                    self.scheduler.get_or_insert_with(Default::default).deadline =
                        (ms > 0).then(|| Duration::from_millis(ms));
                }
                "--adaptive-window" => {
                    let v = parse_bool_flag("--adaptive-window", &take()?)?;
                    self.scheduler.get_or_insert_with(Default::default).adaptive = v;
                }
                "--drain-timeout-ms" => {
                    let ms = take()?.parse::<u64>()?;
                    self.scheduler
                        .get_or_insert_with(Default::default)
                        .drain_timeout = (ms > 0).then(|| Duration::from_millis(ms));
                }
                "--breaker-fail-threshold" => {
                    let t = take()?.parse::<u32>()?;
                    if t == 0 {
                        bail!("--breaker-fail-threshold expects >= 1");
                    }
                    self.breaker.fail_threshold = t;
                }
                "--breaker-cooldown-ms" => {
                    let ms = take()?.parse::<u64>()?;
                    if ms == 0 {
                        bail!("--breaker-cooldown-ms expects >= 1");
                    }
                    self.breaker.cooldown = Duration::from_millis(ms);
                }
                "--chaos" => self.chaos = Some(take()?),
                "--chaos-seed" => self.chaos_seed = take()?.parse::<u64>()?,
                "--idle-timeout-ms" => self.idle_timeout_ms = take()?.parse::<u64>()?,
                "--mux-max-inflight" => {
                    let m = take()?.parse::<usize>()?;
                    if m == 0 {
                        bail!("--mux-max-inflight expects >= 1");
                    }
                    self.mux_max_inflight = m;
                }
                "--mux-chunk-bytes" => self.mux_chunk_bytes = take()?.parse::<usize>()?,
                "--events-buffer" => {
                    let b = take()?.parse::<usize>()?;
                    if b == 0 {
                        bail!("--events-buffer expects >= 1");
                    }
                    self.events_buffer = b;
                }
                "--events-metrics-ms" => self.events_metrics_ms = take()?.parse::<u64>()?,
                "--events-max-subscribers" => {
                    self.events_max_subscribers_per_topic = take()?.parse::<usize>()?;
                }
                "--tenants-file" => {
                    let path = take()?;
                    let text = std::fs::read_to_string(&path)
                        .with_context(|| format!("reading {path}"))?;
                    let v = json::parse(&text).with_context(|| format!("parsing {path}"))?;
                    // A bare array or a `{"tenants": [...]}` wrapper both
                    // work, so a combined server config file round-trips.
                    self.tenants =
                        crate::tenant::parse_tenants(&v).map_err(|e| anyhow!("{path}: {e}"))?;
                }
                "--backend" => self.backend = parse_backend_name("--backend", &take()?)?,
                "--backend-override" => {
                    for spec in take()?.split(',').filter(|s| !s.is_empty()) {
                        let (model, kind) = spec.split_once('=').ok_or_else(|| {
                            anyhow!("--backend-override expects model=kind (got '{spec}')")
                        })?;
                        let kind = parse_backend_name("--backend-override", kind)?
                            .ok_or_else(|| {
                                anyhow!("--backend-override must name a backend, not 'auto'")
                            })?;
                        let model = model.trim().to_string();
                        self.backend_overrides.retain(|(m, _)| *m != model);
                        self.backend_overrides.push((model, kind));
                    }
                }
                "--cpu-workers" => self.cpu_workers = take()?.parse::<usize>()?,
                "--arena-cap-mb" => self.arena_cap_mb = take()?.parse::<usize>()?,
                "--no-verify" => self.verify_sha = false,
                "--no-warmup" => self.warmup = false,
                "--access-log" => self.access_log = true,
                "--audit-log" => self.registry.audit_log = Some(PathBuf::from(take()?)),
                "--guardrail-error-rate" => {
                    let rate = take()?.parse::<f64>()?;
                    if !(0.0..=1.0).contains(&rate) {
                        bail!("--guardrail-error-rate expects 0..=1 (got {rate})");
                    }
                    self.registry.guardrails.max_error_rate = rate;
                }
                "--guardrail-p95-ms" => {
                    self.registry.guardrails.max_p95_us = take()?.parse::<u64>()? * 1000;
                }
                "--guardrail-min-samples" => {
                    self.registry.guardrails.min_samples = take()?.parse::<usize>()?.max(1);
                }
                "--config" => {
                    let path = take()?;
                    let text = std::fs::read_to_string(&path)
                        .with_context(|| format!("reading {path}"))?;
                    self.apply_json(&json::parse(&text)?)?;
                }
                other => bail!("unknown flag '{other}'"),
            }
        }
        Ok(())
    }
}

/// Configuration of the `flexserve gateway` routing tier.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Gateway listen address (port 0 = ephemeral).
    pub addr: String,
    /// HTTP connection worker threads.
    pub http_workers: usize,
    /// Backend replicas as `(id, addr)` pairs. The id is the routing
    /// identity — ring placement hashes it, metrics embed it — so keep it
    /// stable across backend restarts (`name=host:port` spelling); bare
    /// `host:port` uses the address as the id.
    pub backends: Vec<(String, String)>,
    /// Virtual nodes per backend on the consistent-hash ring.
    pub vnodes: usize,
    /// Health probe cadence.
    pub probe_interval: Duration,
    /// Per-probe TCP connect timeout (an unreachable host fails fast).
    pub probe_connect_timeout: Duration,
    /// Per-probe read timeout, distinct from connect: a backend that
    /// accepts but stalls mid-response still fails the probe.
    pub probe_timeout: Duration,
    /// Max extra random sleep added per probe round (0 = none) so a fleet
    /// of gateways doesn't probe every backend in lockstep.
    pub probe_jitter: Duration,
    /// Consecutive failed probes before a backend goes Down (ejected).
    pub fail_after: u32,
    /// Consecutive healthy probes before a backend (re-)admits as Up.
    pub rise_after: u32,
    /// Per-backend concurrent in-flight cap (0 = unbounded). At the cap
    /// the proxy skips to the next replica instead of queueing.
    pub inflight_cap: usize,
    /// Extra attempts after the first on 429/503/transport failure.
    pub retry_budget: u32,
    /// Emit one access-log line per proxied request on stderr.
    pub access_log: bool,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            addr: "127.0.0.1:8081".into(),
            http_workers: 8,
            backends: Vec::new(),
            vnodes: 64,
            probe_interval: Duration::from_millis(500),
            probe_connect_timeout: Duration::from_millis(250),
            probe_timeout: Duration::from_millis(500),
            probe_jitter: Duration::from_millis(25),
            fail_after: 3,
            rise_after: 2,
            inflight_cap: 64,
            retry_budget: 1,
            access_log: false,
        }
    }
}

impl GatewayConfig {
    /// Load from a JSON config file: the `gateway` block of a combined
    /// cluster config, or a bare gateway object.
    pub fn from_file(path: &str) -> Result<GatewayConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let v = json::parse(&text).with_context(|| format!("parsing {path}"))?;
        let block = v.get("gateway").unwrap_or(&v);
        let mut cfg = GatewayConfig::default();
        cfg.apply_json(block)?;
        Ok(cfg)
    }

    pub fn apply_json(&mut self, v: &Value) -> Result<()> {
        for (key, val) in v.as_obj().ok_or_else(|| anyhow!("gateway config must be an object"))? {
            match key.as_str() {
                "addr" => self.addr = req_str(key, val)?.to_string(),
                "http_workers" => self.http_workers = req_usize(key, val)?.max(1),
                "backends" => {
                    let arr = val
                        .as_arr()
                        .ok_or_else(|| anyhow!("'backends' must be an array of strings"))?;
                    self.backends = arr
                        .iter()
                        .map(|b| {
                            b.as_str()
                                .map(parse_backend)
                                .ok_or_else(|| anyhow!("'backends' entries must be strings"))
                        })
                        .collect::<Result<Vec<_>>>()?;
                }
                "vnodes" => self.vnodes = req_usize(key, val)?.max(1),
                "probe_interval_ms" => {
                    self.probe_interval = Duration::from_millis(
                        val.as_u64()
                            .ok_or_else(|| anyhow!("'{key}' must be an integer"))?
                            .max(1),
                    )
                }
                "probe_connect_timeout_ms" => {
                    self.probe_connect_timeout = Duration::from_millis(
                        val.as_u64()
                            .ok_or_else(|| anyhow!("'{key}' must be an integer"))?
                            .max(1),
                    )
                }
                "probe_timeout_ms" => {
                    self.probe_timeout = Duration::from_millis(
                        val.as_u64()
                            .ok_or_else(|| anyhow!("'{key}' must be an integer"))?
                            .max(1),
                    )
                }
                "probe_jitter_ms" => {
                    self.probe_jitter = Duration::from_millis(
                        val.as_u64()
                            .ok_or_else(|| anyhow!("'{key}' must be an integer (0 = no jitter)"))?,
                    )
                }
                "fail_after" => self.fail_after = req_usize(key, val)?.max(1) as u32,
                "rise_after" => self.rise_after = req_usize(key, val)?.max(1) as u32,
                "inflight_cap" => self.inflight_cap = req_usize(key, val)?,
                "retry_budget" => self.retry_budget = req_usize(key, val)? as u32,
                "access_log" => self.access_log = req_bool(key, val)?,
                other => bail!("unknown gateway config key '{other}'"),
            }
        }
        Ok(())
    }

    /// Apply `--key value` / `--key=value` CLI overrides (same flag shape
    /// as `ServeConfig::apply_cli`).
    pub fn apply_cli(&mut self, args: &[String]) -> Result<()> {
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            let (flag, inline) = match arg.split_once('=') {
                Some((f, v)) => (f.to_string(), Some(v.to_string())),
                None => (arg.clone(), None),
            };
            let mut take = || -> Result<String> {
                inline.clone().or_else(|| it.next().cloned()).ok_or_else(|| {
                    anyhow!("flag {flag} requires a value")
                })
            };
            match flag.as_str() {
                "--addr" => self.addr = take()?,
                "--http-workers" => self.http_workers = take()?.parse::<usize>()?.max(1),
                "--backends" => {
                    self.backends = take()?
                        .split(',')
                        .filter(|s| !s.is_empty())
                        .map(parse_backend)
                        .collect();
                }
                "--vnodes" => self.vnodes = take()?.parse::<usize>()?.max(1),
                "--probe-interval-ms" => {
                    self.probe_interval = Duration::from_millis(take()?.parse::<u64>()?.max(1))
                }
                "--probe-connect-timeout-ms" => {
                    self.probe_connect_timeout =
                        Duration::from_millis(take()?.parse::<u64>()?.max(1))
                }
                "--probe-timeout-ms" => {
                    self.probe_timeout = Duration::from_millis(take()?.parse::<u64>()?.max(1))
                }
                "--probe-jitter-ms" => {
                    self.probe_jitter = Duration::from_millis(take()?.parse::<u64>()?)
                }
                "--fail-after" => self.fail_after = take()?.parse::<u32>()?.max(1),
                "--rise-after" => self.rise_after = take()?.parse::<u32>()?.max(1),
                "--inflight-cap" => self.inflight_cap = take()?.parse::<usize>()?,
                "--retry-budget" => self.retry_budget = take()?.parse::<u32>()?,
                "--access-log" => self.access_log = true,
                "--config" => {
                    let path = take()?;
                    let text = std::fs::read_to_string(&path)
                        .with_context(|| format!("reading {path}"))?;
                    let v = json::parse(&text)?;
                    let block = v.get("gateway").unwrap_or(&v);
                    self.apply_json(block)?;
                }
                other => bail!("unknown gateway flag '{other}'"),
            }
        }
        Ok(())
    }
}

/// Parse one backend spec: `name=host:port` or bare `host:port` (the
/// address doubles as the id).
fn parse_backend(spec: &str) -> (String, String) {
    match spec.split_once('=') {
        Some((name, addr)) => (name.trim().to_string(), addr.trim().to_string()),
        None => (spec.trim().to_string(), spec.trim().to_string()),
    }
}

/// Validate a backend spelling from config/CLI. `Ok(None)` for ""/"auto"
/// (defer to each manifest entry); a typed error for unknown names so a
/// typo fails at argument parse, not at first predict. Canonicalizes
/// aliases ("u8" → "quant").
fn parse_backend_name(context: &str, s: &str) -> Result<Option<String>> {
    if s.is_empty() || s == "auto" {
        return Ok(None);
    }
    match crate::runtime::BackendKind::parse(s) {
        Some(k) => Ok(Some(k.as_str().to_string())),
        None => bail!("{context}: unknown backend '{s}' (expected xla|cpu|quant|auto)"),
    }
}

fn parse_bool_flag(flag: &str, v: &str) -> Result<bool> {
    match v {
        "1" | "true" | "on" => Ok(true),
        "0" | "false" | "off" => Ok(false),
        other => bail!("{flag} expects on|off (got '{other}')"),
    }
}

fn req_str<'v>(key: &str, v: &'v Value) -> Result<&'v str> {
    v.as_str().ok_or_else(|| anyhow!("'{key}' must be a string"))
}

fn req_usize(key: &str, v: &Value) -> Result<usize> {
    v.as_usize()
        .ok_or_else(|| anyhow!("'{key}' must be a non-negative integer"))
}

fn req_bool(key: &str, v: &Value) -> Result<bool> {
    v.as_bool().ok_or_else(|| anyhow!("'{key}' must be a bool"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let c = ServeConfig::default();
        assert_eq!(c.device_workers, 1);
        let s = c.scheduler.unwrap();
        assert_eq!(s.queue_cap, 0, "default admission is unbounded");
        assert!(s.deadline.is_none(), "no default deadline");
        assert!(s.adaptive, "adaptive window is the default");
        assert!(s.drain_timeout.is_none(), "default drain waits forever");
        assert!(c.verify_sha);
        assert!(c.chaos.is_none(), "chaos is strictly opt-in");
        assert_eq!(c.breaker.fail_threshold, 5);
        assert_eq!(c.breaker.cooldown, Duration::from_secs(5));
    }

    #[test]
    fn json_overrides() {
        let mut c = ServeConfig::default();
        c.apply_json(
            &json::parse(
                r#"{"addr":"0.0.0.0:9000","http_workers":4,
                    "models":["cnn_s"],
                    "scheduler":{"max_batch":16,"max_delay_us":500,
                                 "queue_cap":64,"deadline_ms":250,"adaptive":false},
                    "verify_sha":false}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.addr, "0.0.0.0:9000");
        assert_eq!(c.http_workers, 4);
        assert_eq!(c.models, Some(vec!["cnn_s".to_string()]));
        let s = c.scheduler.unwrap();
        assert_eq!(s.max_batch, 16);
        assert_eq!(s.max_delay, Duration::from_micros(500));
        assert_eq!(s.queue_cap, 64);
        assert_eq!(s.deadline, Some(Duration::from_millis(250)));
        assert!(!s.adaptive);
        assert!(!c.verify_sha);
    }

    #[test]
    fn legacy_batcher_key_still_parses() {
        let mut c = ServeConfig::default();
        c.apply_json(
            &json::parse(r#"{"batcher":{"max_batch":16,"max_delay_us":500}}"#).unwrap(),
        )
        .unwrap();
        let s = c.scheduler.unwrap();
        assert_eq!(s.max_batch, 16);
        assert_eq!(s.max_delay, Duration::from_micros(500));
    }

    #[test]
    fn scheduler_disable() {
        let mut c = ServeConfig::default();
        c.apply_json(&json::parse(r#"{"scheduler":false}"#).unwrap()).unwrap();
        assert!(c.scheduler.is_none());
        c.apply_json(&json::parse(r#"{"batcher":false}"#).unwrap()).unwrap();
        assert!(c.scheduler.is_none());
        c.apply_json(&json::parse(r#"{"scheduler":true}"#).unwrap()).unwrap();
        assert!(c.scheduler.is_some());
        // deadline_ms 0 = no deadline.
        c.apply_json(&json::parse(r#"{"scheduler":{"deadline_ms":0}}"#).unwrap()).unwrap();
        assert!(c.scheduler.unwrap().deadline.is_none());
    }

    #[test]
    fn chaos_breaker_and_drain_knobs_parse() {
        let mut c = ServeConfig::default();
        c.apply_json(
            &json::parse(
                r#"{"chaos":"exec.device=0.2:panic,sched.flush=0.1:error","chaos_seed":7,
                    "breaker":{"fail_threshold":3,"cooldown_ms":250},
                    "scheduler":{"drain_timeout_ms":1500}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(
            c.chaos.as_deref(),
            Some("exec.device=0.2:panic,sched.flush=0.1:error")
        );
        assert_eq!(c.chaos_seed, 7);
        assert_eq!(c.breaker.fail_threshold, 3);
        assert_eq!(c.breaker.cooldown, Duration::from_millis(250));
        assert_eq!(
            c.scheduler.unwrap().drain_timeout,
            Some(Duration::from_millis(1500))
        );
        // chaos: null switches it back off; drain_timeout_ms 0 = wait forever.
        c.apply_json(
            &json::parse(r#"{"chaos":null,"scheduler":{"drain_timeout_ms":0}}"#).unwrap(),
        )
        .unwrap();
        assert!(c.chaos.is_none());
        assert!(c.scheduler.unwrap().drain_timeout.is_none());
        assert!(ServeConfig::default()
            .apply_json(&json::parse(r#"{"breaker":{"fail_threshold":0}}"#).unwrap())
            .is_err());

        let mut c = ServeConfig::default();
        c.apply_cli(
            &["--chaos=exec.submit=1:error", "--chaos-seed", "99",
              "--breaker-fail-threshold=2", "--breaker-cooldown-ms", "100",
              "--drain-timeout-ms=2000"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
        )
        .unwrap();
        assert_eq!(c.chaos.as_deref(), Some("exec.submit=1:error"));
        assert_eq!(c.chaos_seed, 99);
        assert_eq!(c.breaker.fail_threshold, 2);
        assert_eq!(c.breaker.cooldown, Duration::from_millis(100));
        assert_eq!(
            c.scheduler.unwrap().drain_timeout,
            Some(Duration::from_millis(2000))
        );
        assert!(ServeConfig::default()
            .apply_cli(&["--breaker-cooldown-ms=0".to_string()])
            .is_err());
    }

    #[test]
    fn mux_events_and_idle_knobs_parse() {
        let c = ServeConfig::default();
        assert_eq!(c.idle_timeout_ms, 0, "idle reaping is opt-in");
        assert_eq!(c.mux_max_inflight, 32);
        assert_eq!(c.mux_chunk_bytes, 64 << 10);
        assert_eq!(c.events_buffer, 256);
        assert_eq!(c.events_metrics_ms, 5000);

        let mut c = ServeConfig::default();
        c.apply_json(
            &json::parse(
                r#"{"idle_timeout_ms":30000,
                    "mux":{"max_inflight":8,"chunk_bytes":4096},
                    "events":{"buffer":64,"metrics_interval_ms":1000}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.idle_timeout_ms, 30000);
        assert_eq!(c.mux_max_inflight, 8);
        assert_eq!(c.mux_chunk_bytes, 4096);
        assert_eq!(c.events_buffer, 64);
        assert_eq!(c.events_metrics_ms, 1000);
        assert!(ServeConfig::default()
            .apply_json(&json::parse(r#"{"mux":{"max_inflight":0}}"#).unwrap())
            .is_err());
        assert!(ServeConfig::default()
            .apply_json(&json::parse(r#"{"events":{"buffer":0}}"#).unwrap())
            .is_err());

        let mut c = ServeConfig::default();
        c.apply_cli(
            &["--idle-timeout-ms=15000", "--mux-max-inflight", "16",
              "--mux-chunk-bytes=1024", "--events-buffer", "32",
              "--events-metrics-ms=0"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
        )
        .unwrap();
        assert_eq!(c.idle_timeout_ms, 15000);
        assert_eq!(c.mux_max_inflight, 16);
        assert_eq!(c.mux_chunk_bytes, 1024);
        assert_eq!(c.events_buffer, 32);
        assert_eq!(c.events_metrics_ms, 0);
        assert!(ServeConfig::default()
            .apply_cli(&["--mux-max-inflight=0".to_string()])
            .is_err());
        assert!(ServeConfig::default()
            .apply_cli(&["--events-buffer=0".to_string()])
            .is_err());
    }

    #[test]
    fn tenants_block_and_events_cap_parse() {
        let c = ServeConfig::default();
        assert!(c.tenants.is_empty(), "open mode is the default");
        assert_eq!(c.events_max_subscribers_per_topic, 0, "0 = unlimited");

        let mut c = ServeConfig::default();
        c.apply_json(
            &json::parse(
                r#"{"tenants":{"acme":{"key":"acme-key","weight":3,"rate_rps":50,
                               "burst":100,"queue_quota":64},
                       "beta":{"key":"beta-key"}},
                    "events":{"max_subscribers_per_topic":4}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.tenants.len(), 2);
        let acme = c.tenants.iter().find(|t| t.id == "acme").unwrap();
        assert_eq!(acme.weight, 3);
        assert_eq!(acme.queue_quota, 64);
        assert_eq!(acme.key_sha256, crate::tenant::hash_key("acme-key"));
        assert_eq!(c.events_max_subscribers_per_topic, 4);
        // tenants: null switches back to open mode.
        c.apply_json(&json::parse(r#"{"tenants":null}"#).unwrap()).unwrap();
        assert!(c.tenants.is_empty());
        // The reserved anonymous id is a parse error, not a silent shadow.
        assert!(ServeConfig::default()
            .apply_json(&json::parse(r#"{"tenants":{"anonymous":{"key":"k"}}}"#).unwrap())
            .is_err());

        let dir = std::env::temp_dir().join("flexserve_cfg_tenants_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tenants.json");
        std::fs::write(&path, r#"{"tenants":{"acme":{"key":"k1","weight":2}}}"#).unwrap();
        let mut c = ServeConfig::default();
        c.apply_cli(&[
            format!("--tenants-file={}", path.display()),
            "--events-max-subscribers=2".to_string(),
        ])
        .unwrap();
        assert_eq!(c.tenants.len(), 1);
        assert_eq!(c.tenants[0].id, "acme");
        assert_eq!(c.tenants[0].weight, 2);
        assert_eq!(c.events_max_subscribers_per_topic, 2);
        assert!(ServeConfig::default()
            .apply_cli(&["--tenants-file=/definitely/not/there.json".to_string()])
            .is_err());
    }

    #[test]
    fn backend_block_and_flags_parse() {
        let c = ServeConfig::default();
        assert!(c.backend.is_none(), "default defers to the manifest");
        assert!(c.backend_overrides.is_empty());
        assert_eq!(c.cpu_workers, 0, "0 = auto-size");
        assert_eq!(c.arena_cap_mb, 0, "0 = built-in default cap");

        let mut c = ServeConfig::default();
        c.apply_json(
            &json::parse(
                r#"{"backend":{"default":"cpu","models":{"cnn_s":"u8","mlp":"xla"},
                    "cpu_workers":4,"arena_cap_mb":128}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.backend.as_deref(), Some("cpu"));
        assert_eq!(
            c.backend_overrides,
            vec![
                ("cnn_s".to_string(), "quant".to_string()), // "u8" canonicalizes
                ("mlp".to_string(), "xla".to_string()),
            ]
        );
        assert_eq!(c.cpu_workers, 4);
        assert_eq!(c.arena_cap_mb, 128);
        // String shorthand pins the default; "auto" clears it.
        c.apply_json(&json::parse(r#"{"backend":"quant"}"#).unwrap()).unwrap();
        assert_eq!(c.backend.as_deref(), Some("quant"));
        c.apply_json(&json::parse(r#"{"backend":{"default":"auto"}}"#).unwrap()).unwrap();
        assert!(c.backend.is_none());
        // Unknown names are a parse error, not a deferred 409.
        assert!(ServeConfig::default()
            .apply_json(&json::parse(r#"{"backend":"tpu"}"#).unwrap())
            .is_err());
        assert!(ServeConfig::default()
            .apply_json(&json::parse(r#"{"backend":{"models":{"cnn_s":"auto"}}}"#).unwrap())
            .is_err());

        let mut c = ServeConfig::default();
        c.apply_cli(
            &["--backend=cpu", "--backend-override", "cnn_s=quant,cnn_m=xla",
              "--cpu-workers", "2", "--arena-cap-mb=32"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
        )
        .unwrap();
        assert_eq!(c.backend.as_deref(), Some("cpu"));
        assert_eq!(c.backend_overrides.len(), 2);
        assert_eq!(c.cpu_workers, 2);
        assert_eq!(c.arena_cap_mb, 32);
        // A repeated override for the same model replaces, not duplicates.
        c.apply_cli(&["--backend-override=cnn_s=xla".to_string()]).unwrap();
        assert_eq!(
            c.backend_overrides.iter().filter(|(m, _)| m == "cnn_s").count(),
            1
        );
        assert!(c
            .backend_overrides
            .contains(&("cnn_s".to_string(), "xla".to_string())));
        assert!(ServeConfig::default()
            .apply_cli(&["--backend=gpu".to_string()])
            .is_err());
        assert!(ServeConfig::default()
            .apply_cli(&["--backend-override=cnn_s".to_string()])
            .is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = ServeConfig::default();
        assert!(c.apply_json(&json::parse(r#"{"nope":1}"#).unwrap()).is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut c = ServeConfig::default();
        let args: Vec<String> = [
            "--addr=127.0.0.1:0",
            "--device-workers",
            "2",
            "--models",
            "cnn_s,mlp",
            "--batch-delay-us=1000",
            "--queue-cap=8",
            "--deadline-ms",
            "500",
            "--adaptive-window=off",
            "--no-verify",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        c.apply_cli(&args).unwrap();
        assert_eq!(c.addr, "127.0.0.1:0");
        assert_eq!(c.device_workers, 2);
        assert_eq!(
            c.models,
            Some(vec!["cnn_s".to_string(), "mlp".to_string()])
        );
        let s = c.scheduler.unwrap();
        assert_eq!(s.max_delay, Duration::from_micros(1000));
        assert_eq!(s.queue_cap, 8);
        assert_eq!(s.deadline, Some(Duration::from_millis(500)));
        assert!(!s.adaptive);
        assert!(!c.verify_sha);
        assert!(ServeConfig::default()
            .apply_cli(&["--adaptive-window=maybe".to_string()])
            .is_err());
    }

    #[test]
    fn registry_block_and_flags_parse() {
        let mut c = ServeConfig::default();
        assert!(c.registry.audit_log.is_none(), "audit file is opt-in");
        c.apply_json(
            &json::parse(
                r#"{"registry":{"audit_log":"/tmp/audit.jsonl","max_error_rate":0.25,
                    "max_p95_ms":40,"min_samples":8}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(
            c.registry.audit_log.as_deref(),
            Some(std::path::Path::new("/tmp/audit.jsonl"))
        );
        assert!((c.registry.guardrails.max_error_rate - 0.25).abs() < 1e-9);
        assert_eq!(c.registry.guardrails.max_p95_us, 40_000);
        assert_eq!(c.registry.guardrails.min_samples, 8);
        // audit_log: null turns the file sink back off.
        c.apply_json(&json::parse(r#"{"registry":{"audit_log":null}}"#).unwrap()).unwrap();
        assert!(c.registry.audit_log.is_none());

        let mut c = ServeConfig::default();
        c.apply_cli(
            &["--audit-log=/tmp/a.jsonl", "--guardrail-error-rate", "0.1",
              "--guardrail-p95-ms=25", "--guardrail-min-samples", "5"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
        )
        .unwrap();
        assert!(c.registry.audit_log.is_some());
        assert!((c.registry.guardrails.max_error_rate - 0.1).abs() < 1e-9);
        assert_eq!(c.registry.guardrails.max_p95_us, 25_000);
        assert_eq!(c.registry.guardrails.min_samples, 5);
        assert!(ServeConfig::default()
            .apply_cli(&["--guardrail-error-rate=7".to_string()])
            .is_err());
        assert!(ServeConfig::default()
            .apply_json(&json::parse(r#"{"registry":{"max_error_rate":7}}"#).unwrap())
            .is_err());
    }

    #[test]
    fn example_config_file_parses() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/server.example.json");
        let c = ServeConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.addr, "0.0.0.0:8080");
        assert_eq!(c.models.as_ref().unwrap().len(), 3);
        let s = c.scheduler.unwrap();
        assert_eq!(s.max_delay, Duration::from_micros(2000));
        assert_eq!(s.queue_cap, 1024);
        assert!(s.adaptive);
        assert_eq!(s.drain_timeout, Some(Duration::from_millis(5000)));
        assert!(c.chaos.is_none(), "example ships with chaos off");
        assert_eq!(c.breaker.fail_threshold, 5);
        assert_eq!(
            c.registry.audit_log.as_deref(),
            Some(std::path::Path::new("flexserve_audit.jsonl"))
        );
        assert_eq!(c.registry.guardrails.min_samples, 20);
        assert_eq!(c.idle_timeout_ms, 0);
        assert_eq!(c.mux_max_inflight, 32);
        assert_eq!(c.mux_chunk_bytes, 65536);
        assert_eq!(c.events_buffer, 256);
        assert_eq!(c.events_metrics_ms, 5000);
        assert_eq!(c.events_max_subscribers_per_topic, 0);
        assert_eq!(c.tenants.len(), 2, "example ships two keyed tenants");
        let acme = c.tenants.iter().find(|t| t.id == "acme").unwrap();
        assert_eq!(acme.weight, 3);
        assert_eq!(acme.queue_quota, 256);
        assert!(c.backend.is_none(), "example ships with backend auto");
        assert!(c.backend_overrides.is_empty());
        assert_eq!(c.cpu_workers, 0);
        assert_eq!(c.arena_cap_mb, 64);
    }

    #[test]
    fn gateway_json_and_cli_parse() {
        let mut g = GatewayConfig::default();
        g.apply_json(
            &json::parse(
                r#"{"addr":"0.0.0.0:8081","backends":["a=127.0.0.1:9001","127.0.0.1:9002"],
                    "vnodes":128,"probe_interval_ms":250,"probe_timeout_ms":100,
                    "probe_connect_timeout_ms":50,"probe_jitter_ms":0,
                    "fail_after":2,"rise_after":1,"inflight_cap":32,"retry_budget":3}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(g.addr, "0.0.0.0:8081");
        assert_eq!(
            g.backends,
            vec![
                ("a".to_string(), "127.0.0.1:9001".to_string()),
                ("127.0.0.1:9002".to_string(), "127.0.0.1:9002".to_string()),
            ]
        );
        assert_eq!(g.vnodes, 128);
        assert_eq!(g.probe_interval, Duration::from_millis(250));
        assert_eq!(g.probe_timeout, Duration::from_millis(100));
        assert_eq!(g.probe_connect_timeout, Duration::from_millis(50));
        assert_eq!(g.probe_jitter, Duration::ZERO);
        assert_eq!(g.fail_after, 2);
        assert_eq!(g.rise_after, 1);
        assert_eq!(g.inflight_cap, 32);
        assert_eq!(g.retry_budget, 3);
        assert!(g
            .apply_json(&json::parse(r#"{"nope":1}"#).unwrap())
            .is_err());

        let mut g = GatewayConfig::default();
        g.apply_cli(
            &["--addr=127.0.0.1:0", "--backends", "b1=127.0.0.1:9001,b2=127.0.0.1:9002",
              "--retry-budget=2", "--probe-interval-ms", "100",
              "--probe-connect-timeout-ms=40", "--probe-jitter-ms=10"]
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>(),
        )
        .unwrap();
        assert_eq!(g.backends.len(), 2);
        assert_eq!(g.backends[0].0, "b1");
        assert_eq!(g.retry_budget, 2);
        assert_eq!(g.probe_interval, Duration::from_millis(100));
        assert_eq!(g.probe_connect_timeout, Duration::from_millis(40));
        assert_eq!(g.probe_jitter, Duration::from_millis(10));
        assert!(GatewayConfig::default()
            .apply_cli(&["--bogus".to_string()])
            .is_err());
    }

    #[test]
    fn gateway_block_from_combined_config_file() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/server.example.json");
        let g = GatewayConfig::from_file(path.to_str().unwrap()).unwrap();
        assert!(!g.backends.is_empty(), "example config lists backends");
        // And the serve side tolerates the same file (gateway block ignored).
        let c = ServeConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.addr, "0.0.0.0:8080");
    }

    #[test]
    fn cli_no_batcher_and_bad_flag() {
        let mut c = ServeConfig::default();
        c.apply_cli(&["--no-batcher".to_string()]).unwrap();
        assert!(c.scheduler.is_none());
        assert!(c.apply_cli(&["--bogus".to_string()]).is_err());
        assert!(c.apply_cli(&["--addr".to_string()]).is_err());
    }
}
