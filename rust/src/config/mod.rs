//! Server configuration: JSON config file + CLI-style overrides (clap is
//! unavailable offline; the flag parser lives here and serves `main.rs`).

use crate::coordinator::BatcherConfig;
use crate::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::path::PathBuf;
use std::time::Duration;

/// Full serving configuration (defaults match `flexserve serve` docs).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address, e.g. "127.0.0.1:8080" (port 0 = ephemeral).
    pub addr: String,
    /// HTTP connection worker threads (Gunicorn-worker analogue).
    pub http_workers: usize,
    /// Device executor threads, each owning a full PJRT client + ensemble.
    pub device_workers: usize,
    /// Artifact directory (produced by `make artifacts`).
    pub artifacts: PathBuf,
    /// Verify every artifact SHA-256 against the manifest at startup.
    pub verify_sha: bool,
    /// Run a warmup forward per executable at startup.
    pub warmup: bool,
    /// Restrict the served model set (None = all models in the manifest).
    pub models: Option<Vec<String>>,
    /// Dynamic batcher (None = pass-through, the paper's base behaviour).
    pub batcher: Option<BatcherConfig>,
    /// Emit one access-log line per request on stderr (router middleware).
    pub access_log: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8080".into(),
            http_workers: 8,
            device_workers: 1, // one shared device, per the paper
            artifacts: crate::runtime::manifest::default_artifact_dir(),
            verify_sha: true,
            warmup: true,
            models: None,
            batcher: Some(BatcherConfig::default()),
            access_log: false,
        }
    }
}

impl ServeConfig {
    /// Load from a JSON config file.
    pub fn from_file(path: &str) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let v = json::parse(&text).with_context(|| format!("parsing {path}"))?;
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&v)?;
        Ok(cfg)
    }

    fn apply_json(&mut self, v: &Value) -> Result<()> {
        for (key, val) in v.as_obj().ok_or_else(|| anyhow!("config must be an object"))? {
            self.set(key, val)?;
        }
        Ok(())
    }

    fn set(&mut self, key: &str, val: &Value) -> Result<()> {
        match key {
            "addr" => self.addr = req_str(key, val)?.to_string(),
            "http_workers" => self.http_workers = req_usize(key, val)?.max(1),
            "device_workers" => self.device_workers = req_usize(key, val)?.max(1),
            "artifacts" => self.artifacts = PathBuf::from(req_str(key, val)?),
            "verify_sha" => self.verify_sha = req_bool(key, val)?,
            "warmup" => self.warmup = req_bool(key, val)?,
            "access_log" => self.access_log = req_bool(key, val)?,
            "models" => {
                let arr = val
                    .as_arr()
                    .ok_or_else(|| anyhow!("'models' must be an array"))?;
                let names = arr
                    .iter()
                    .map(|m| {
                        m.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| anyhow!("'models' entries must be strings"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                self.models = if names.is_empty() { None } else { Some(names) };
            }
            "batcher" => match val {
                Value::Null | Value::Bool(false) => self.batcher = None,
                Value::Bool(true) => self.batcher = Some(BatcherConfig::default()),
                Value::Obj(_) => {
                    let mut cfg = self.batcher.unwrap_or_default();
                    if let Some(mb) = val.get("max_batch") {
                        cfg.max_batch = mb
                            .as_usize()
                            .ok_or_else(|| anyhow!("batcher.max_batch must be an integer"))?
                            .max(1);
                    }
                    if let Some(d) = val.get("max_delay_us") {
                        cfg.max_delay = Duration::from_micros(
                            d.as_u64()
                                .ok_or_else(|| anyhow!("batcher.max_delay_us must be an integer"))?,
                        );
                    }
                    self.batcher = Some(cfg);
                }
                _ => bail!("'batcher' must be bool, null, or object"),
            },
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Apply `--key value` / `--key=value` CLI overrides. Recognized keys
    /// mirror the JSON config (`--addr`, `--http-workers`,
    /// `--device-workers`, `--artifacts`, `--models a,b`, `--no-batcher`,
    /// `--batch-delay-us N`, `--max-batch N`, `--no-verify`, `--no-warmup`,
    /// `--access-log`).
    pub fn apply_cli(&mut self, args: &[String]) -> Result<()> {
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            let (flag, inline) = match arg.split_once('=') {
                Some((f, v)) => (f.to_string(), Some(v.to_string())),
                None => (arg.clone(), None),
            };
            let mut take = || -> Result<String> {
                inline.clone().or_else(|| it.next().cloned()).ok_or_else(|| {
                    anyhow!("flag {flag} requires a value")
                })
            };
            match flag.as_str() {
                "--addr" => self.addr = take()?,
                "--http-workers" => self.http_workers = take()?.parse::<usize>()?.max(1),
                "--device-workers" => self.device_workers = take()?.parse::<usize>()?.max(1),
                "--artifacts" => self.artifacts = PathBuf::from(take()?),
                "--models" => {
                    self.models = Some(
                        take()?
                            .split(',')
                            .filter(|s| !s.is_empty())
                            .map(str::to_string)
                            .collect(),
                    )
                }
                "--no-batcher" => self.batcher = None,
                "--max-batch" => {
                    let v = take()?.parse::<usize>()?.max(1);
                    self.batcher.get_or_insert_with(Default::default).max_batch = v;
                }
                "--batch-delay-us" => {
                    let v = Duration::from_micros(take()?.parse()?);
                    self.batcher.get_or_insert_with(Default::default).max_delay = v;
                }
                "--no-verify" => self.verify_sha = false,
                "--no-warmup" => self.warmup = false,
                "--access-log" => self.access_log = true,
                "--config" => {
                    let path = take()?;
                    let text = std::fs::read_to_string(&path)
                        .with_context(|| format!("reading {path}"))?;
                    self.apply_json(&json::parse(&text)?)?;
                }
                other => bail!("unknown flag '{other}'"),
            }
        }
        Ok(())
    }
}

fn req_str<'v>(key: &str, v: &'v Value) -> Result<&'v str> {
    v.as_str().ok_or_else(|| anyhow!("'{key}' must be a string"))
}

fn req_usize(key: &str, v: &Value) -> Result<usize> {
    v.as_usize()
        .ok_or_else(|| anyhow!("'{key}' must be a non-negative integer"))
}

fn req_bool(key: &str, v: &Value) -> Result<bool> {
    v.as_bool().ok_or_else(|| anyhow!("'{key}' must be a bool"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let c = ServeConfig::default();
        assert_eq!(c.device_workers, 1);
        assert!(c.batcher.is_some());
        assert!(c.verify_sha);
    }

    #[test]
    fn json_overrides() {
        let mut c = ServeConfig::default();
        c.apply_json(
            &json::parse(
                r#"{"addr":"0.0.0.0:9000","http_workers":4,
                    "models":["cnn_s"],"batcher":{"max_batch":16,"max_delay_us":500},
                    "verify_sha":false}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.addr, "0.0.0.0:9000");
        assert_eq!(c.http_workers, 4);
        assert_eq!(c.models, Some(vec!["cnn_s".to_string()]));
        let b = c.batcher.unwrap();
        assert_eq!(b.max_batch, 16);
        assert_eq!(b.max_delay, Duration::from_micros(500));
        assert!(!c.verify_sha);
    }

    #[test]
    fn batcher_disable() {
        let mut c = ServeConfig::default();
        c.apply_json(&json::parse(r#"{"batcher":false}"#).unwrap()).unwrap();
        assert!(c.batcher.is_none());
        c.apply_json(&json::parse(r#"{"batcher":true}"#).unwrap()).unwrap();
        assert!(c.batcher.is_some());
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = ServeConfig::default();
        assert!(c.apply_json(&json::parse(r#"{"nope":1}"#).unwrap()).is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut c = ServeConfig::default();
        let args: Vec<String> = [
            "--addr=127.0.0.1:0",
            "--device-workers",
            "2",
            "--models",
            "cnn_s,mlp",
            "--batch-delay-us=1000",
            "--no-verify",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        c.apply_cli(&args).unwrap();
        assert_eq!(c.addr, "127.0.0.1:0");
        assert_eq!(c.device_workers, 2);
        assert_eq!(
            c.models,
            Some(vec!["cnn_s".to_string(), "mlp".to_string()])
        );
        assert_eq!(
            c.batcher.unwrap().max_delay,
            Duration::from_micros(1000)
        );
        assert!(!c.verify_sha);
    }

    #[test]
    fn example_config_file_parses() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/server.example.json");
        let c = ServeConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.addr, "0.0.0.0:8080");
        assert_eq!(c.models.as_ref().unwrap().len(), 3);
        assert_eq!(c.batcher.unwrap().max_delay, Duration::from_micros(2000));
    }

    #[test]
    fn cli_no_batcher_and_bad_flag() {
        let mut c = ServeConfig::default();
        c.apply_cli(&["--no-batcher".to_string()]).unwrap();
        assert!(c.batcher.is_none());
        assert!(c.apply_cli(&["--bogus".to_string()]).is_err());
        assert!(c.apply_cli(&["--addr".to_string()]).is_err());
    }
}
