//! Deterministic fault-injection plane: seeded, named-site chaos for the
//! failure-containment subsystem.
//!
//! A chaos spec is a comma-separated list of `site=rate:kind` rules
//! (`--chaos 'exec.device=0.2:panic,gateway.connect=0.1:drop'`): `site`
//! names one of the fixed injection points threaded through the stack,
//! `rate` is the per-decision injection probability in `(0, 1]`, and
//! `kind` selects the failure mode:
//!
//! * `panic` — the site panics (exercises `catch_unwind` supervision and
//!   the router's panic→500 middleware);
//! * `error` — the site returns a synthetic error;
//! * `drop`  — the site abandons the work (connection dropped / job
//!   discarded); sites without a natural "drop" semantics degrade it to
//!   `error`, so a spec never silently no-ops.
//!
//! Sites (one constant each, grep for call sites):
//!
//! | site              | boundary                                        |
//! |-------------------|-------------------------------------------------|
//! | `exec.submit`     | [`ExecutorHandle::infer_async`] channel send    |
//! | `exec.device`     | device thread, before `execute_job`             |
//! | `sched.flush`     | scheduler flush, before the target forward      |
//! | `gateway.connect` | gateway proxy backend connection checkout       |
//! | `gateway.probe`   | gateway health probe (forces `Unreachable`)     |
//!
//! Decisions draw from a per-rule [`Prng`] stream forked from one seed, so
//! a given spec + seed replays the same injection sequence per site
//! (modulo thread interleaving across sites). The plane is installed
//! process-wide at most once ([`install`]); when nothing is installed,
//! [`decide`] is a single atomic load — the disabled hot path costs
//! nothing. Every injection bumps a per-site counter; with a metrics sink
//! registered ([`set_sink`]) it also lands as `chaos_inject_<site>_total`
//! in all three metric expositions.
//!
//! [`ExecutorHandle::infer_async`]: crate::runtime::ExecutorHandle::infer_async

use crate::coordinator::metrics::Metrics;
use crate::util::Prng;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

pub const EXEC_SUBMIT: &str = "exec.submit";
pub const EXEC_DEVICE: &str = "exec.device";
pub const SCHED_FLUSH: &str = "sched.flush";
pub const GATEWAY_CONNECT: &str = "gateway.connect";
pub const GATEWAY_PROBE: &str = "gateway.probe";

/// Every named injection site (the spec parser validates against this).
pub const SITES: &[&str] = &[
    EXEC_SUBMIT,
    EXEC_DEVICE,
    SCHED_FLUSH,
    GATEWAY_CONNECT,
    GATEWAY_PROBE,
];

/// What an armed site does when its rate fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Panic,
    Error,
    Drop,
}

impl FaultKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::Error => "error",
            FaultKind::Drop => "drop",
        }
    }
}

struct Rule {
    site: &'static str,
    rate: f64,
    kind: FaultKind,
    prng: Mutex<Prng>,
    injected: AtomicU64,
    /// Pre-rendered counter name (`chaos_inject_exec_device_total`) so the
    /// injection path never formats.
    metric: String,
}

/// A parsed, seeded injector. Usually installed process-wide via
/// [`install`]; harnesses may also hold one directly.
pub struct ChaosPlane {
    rules: Vec<Rule>,
    armed: AtomicBool,
    sink: OnceLock<Arc<Metrics>>,
}

impl ChaosPlane {
    /// Parse a `site=rate:kind[,site=rate:kind...]` spec.
    pub fn parse(spec: &str, seed: u64) -> Result<ChaosPlane> {
        let mut root = Prng::new(seed);
        let mut rules: Vec<Rule> = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (site_s, rest) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("chaos rule '{part}': expected site=rate:kind"))?;
            let (rate_s, kind_s) = rest
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("chaos rule '{part}': expected site=rate:kind"))?;
            let Some(&site) = SITES.iter().find(|s| **s == site_s.trim()) else {
                bail!(
                    "chaos rule '{part}': unknown site '{site_s}' (one of: {})",
                    SITES.join(", ")
                );
            };
            if rules.iter().any(|r| r.site == site) {
                bail!("chaos rule '{part}': site '{site}' listed twice");
            }
            let rate: f64 = rate_s
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("chaos rule '{part}': rate '{rate_s}' is not a number"))?;
            if !(rate > 0.0 && rate <= 1.0) {
                bail!("chaos rule '{part}': rate must be in (0, 1], got {rate}");
            }
            let kind = match kind_s.trim() {
                "panic" => FaultKind::Panic,
                "error" => FaultKind::Error,
                "drop" => FaultKind::Drop,
                other => bail!("chaos rule '{part}': unknown kind '{other}' (panic, error, drop)"),
            };
            rules.push(Rule {
                site,
                rate,
                kind,
                prng: Mutex::new(root.fork()),
                injected: AtomicU64::new(0),
                metric: format!("chaos_inject_{}_total", site.replace('.', "_")),
            });
        }
        if rules.is_empty() {
            bail!("chaos spec is empty (expected site=rate:kind[,...])");
        }
        Ok(ChaosPlane {
            rules,
            armed: AtomicBool::new(true),
            sink: OnceLock::new(),
        })
    }

    /// Should `site` fail right now? Draws the site's seeded stream and
    /// meters the injection. `None` = proceed normally.
    pub fn decide(&self, site: &str) -> Option<FaultKind> {
        if !self.armed.load(Ordering::Relaxed) {
            return None;
        }
        let rule = self.rules.iter().find(|r| r.site == site)?;
        if !rule.prng.lock().unwrap().bool(rule.rate) {
            return None;
        }
        rule.injected.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = self.sink.get() {
            m.inc(&rule.metric);
        }
        Some(rule.kind)
    }

    /// Injections fired at one site so far.
    pub fn injected(&self, site: &str) -> u64 {
        self.rules
            .iter()
            .find(|r| r.site == site)
            .map(|r| r.injected.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Arm/disarm the whole plane (a disarmed plane never injects —
    /// harnesses use this to run clean recovery phases after a fault
    /// phase without reinstalling).
    pub fn set_armed(&self, on: bool) {
        self.armed.store(on, Ordering::Relaxed);
    }

    /// Register the metrics registry injections are counted into (first
    /// call wins). Without a sink the per-plane counters still track.
    pub fn set_sink(&self, metrics: Arc<Metrics>) {
        let _ = self.sink.set(metrics);
    }

    /// One-line human summary for the serve banner.
    pub fn summary(&self) -> String {
        self.rules
            .iter()
            .map(|r| format!("{}={}:{}", r.site, r.rate, r.kind.as_str()))
            .collect::<Vec<_>>()
            .join(",")
    }
}

static GLOBAL: OnceLock<ChaosPlane> = OnceLock::new();

/// Install the process-wide plane (at most once; a second install fails
/// rather than silently replacing an active injector).
pub fn install(plane: ChaosPlane) -> Result<()> {
    GLOBAL
        .set(plane)
        .map_err(|_| anyhow::anyhow!("chaos plane already installed"))
}

/// The installed plane, if any.
pub fn global() -> Option<&'static ChaosPlane> {
    GLOBAL.get()
}

/// Process-wide injection decision for `site`. With no plane installed
/// this is one atomic load and `None`.
pub fn decide(site: &str) -> Option<FaultKind> {
    GLOBAL.get().and_then(|p| p.decide(site))
}

/// Arm/disarm the installed plane (no-op when none is installed).
pub fn set_armed(on: bool) {
    if let Some(p) = GLOBAL.get() {
        p.set_armed(on);
    }
}

/// Point the installed plane's injection counters at a metrics registry.
pub fn set_sink(metrics: Arc<Metrics>) {
    if let Some(p) = GLOBAL.get() {
        p.set_sink(metrics);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_multi_site_specs() {
        let p = ChaosPlane::parse("exec.device=0.5:panic, gateway.connect=1.0:drop", 7).unwrap();
        assert_eq!(p.rules.len(), 2);
        assert_eq!(p.rules[0].kind, FaultKind::Panic);
        assert_eq!(p.rules[1].site, GATEWAY_CONNECT);
        assert_eq!(
            p.summary(),
            "exec.device=0.5:panic,gateway.connect=1:drop"
        );
    }

    #[test]
    fn rejects_malformed_specs() {
        for (spec, frag) in [
            ("", "empty"),
            ("exec.device", "expected site=rate:kind"),
            ("exec.device=0.5", "expected site=rate:kind"),
            ("bogus.site=0.5:panic", "unknown site"),
            ("exec.device=0:panic", "rate must be in"),
            ("exec.device=1.5:panic", "rate must be in"),
            ("exec.device=x:panic", "not a number"),
            ("exec.device=0.5:explode", "unknown kind"),
            ("exec.device=0.5:panic,exec.device=0.1:error", "listed twice"),
        ] {
            let e = ChaosPlane::parse(spec, 1).unwrap_err().to_string();
            assert!(e.contains(frag), "{spec}: {e}");
        }
    }

    #[test]
    fn rate_one_always_fires_and_counts() {
        let p = ChaosPlane::parse("sched.flush=1.0:error", 3).unwrap();
        for _ in 0..10 {
            assert_eq!(p.decide(SCHED_FLUSH), Some(FaultKind::Error));
        }
        assert_eq!(p.injected(SCHED_FLUSH), 10);
        // Unlisted sites never fire.
        assert_eq!(p.decide(EXEC_DEVICE), None);
        assert_eq!(p.injected(EXEC_DEVICE), 0);
    }

    #[test]
    fn seeded_decisions_replay() {
        let a = ChaosPlane::parse("exec.device=0.3:error", 42).unwrap();
        let b = ChaosPlane::parse("exec.device=0.3:error", 42).unwrap();
        let da: Vec<bool> = (0..64).map(|_| a.decide(EXEC_DEVICE).is_some()).collect();
        let db: Vec<bool> = (0..64).map(|_| b.decide(EXEC_DEVICE).is_some()).collect();
        assert_eq!(da, db);
        assert!(da.iter().any(|&x| x) && da.iter().any(|&x| !x));
        // A different seed draws a different sequence.
        let c = ChaosPlane::parse("exec.device=0.3:error", 43).unwrap();
        let dc: Vec<bool> = (0..64).map(|_| c.decide(EXEC_DEVICE).is_some()).collect();
        assert_ne!(da, dc);
    }

    #[test]
    fn disarmed_plane_never_fires() {
        let p = ChaosPlane::parse("sched.flush=1.0:error", 3).unwrap();
        p.set_armed(false);
        assert_eq!(p.decide(SCHED_FLUSH), None);
        assert_eq!(p.injected(SCHED_FLUSH), 0);
        p.set_armed(true);
        assert_eq!(p.decide(SCHED_FLUSH), Some(FaultKind::Error));
    }

    #[test]
    fn injections_land_in_the_metrics_sink() {
        let p = ChaosPlane::parse("gateway.probe=1.0:error", 5).unwrap();
        let m = Arc::new(Metrics::new());
        p.set_sink(Arc::clone(&m));
        p.decide(GATEWAY_PROBE);
        p.decide(GATEWAY_PROBE);
        assert_eq!(m.counter("chaos_inject_gateway_probe_total"), 2);
        assert!(m
            .render_prometheus()
            .contains("flexserve_chaos_inject_gateway_probe_total"));
    }
}
