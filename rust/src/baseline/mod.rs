//! The comparison baseline: a TensorFlow-Serving-style deployment model.
//!
//! The paper contrasts FlexServe against serving stacks where (a) each
//! model sits behind its **own** endpoint, (b) batch shape is **fixed** per
//! deployed model, and (c) the input transform runs **per model** because
//! each endpoint owns its preprocessing. This module implements exactly
//! that deployment so the benches can measure the difference on equal
//! hardware:
//!
//! * `POST /v1/models/:name/predict` — one endpoint per model (TFS URL
//!   shape), body `{"data": [...]}`.
//! * Requests whose batch ≠ the deployment's `fixed_batch` are rejected
//!   with 422 (clients must pad/loop, as with a fixed-shape TFS
//!   SavedModel).
//! * Each model runs on its **own** PJRT client (own device memory) —
//!   the "unshared" memory layout of one-model-per-process serving.
//! * The normalization transform executes inside each model's handler —
//!   once per model, not once per request.

use crate::coordinator::Metrics;
use crate::http::{Response, Router, Server, ServerHandle};
use crate::imagepipe::Normalizer;
use crate::json::{self, Value};
use crate::runtime::executor::{ExecRequest, ExecutorOptions};
use crate::runtime::tensor::argmax_rows;
use crate::runtime::{Executor, ExecutorHandle, Manifest};
use crate::util::Stopwatch;
use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct BaselineConfig {
    pub addr: String,
    pub http_workers: usize,
    pub artifacts: PathBuf,
    /// The one batch shape each endpoint accepts (TFS fixed-shape model).
    pub fixed_batch: usize,
    /// Models to deploy (None = all).
    pub models: Option<Vec<String>>,
}

impl Default for BaselineConfig {
    fn default() -> Self {
        BaselineConfig {
            addr: "127.0.0.1:8081".into(),
            http_workers: 8,
            artifacts: crate::runtime::manifest::default_artifact_dir(),
            fixed_batch: 1,
            models: None,
        }
    }
}

pub struct BaselineState {
    pub manifest: Arc<Manifest>,
    /// (model name, its own device client, its own transform).
    pub models: Vec<(String, ExecutorHandle, Normalizer)>,
    pub fixed_batch: usize,
    pub metrics: Arc<Metrics>,
    // Keep executors alive (one PJRT client per model — unshared memory).
    _executors: Vec<Executor>,
}

/// Start the baseline server.
pub fn serve_baseline(config: &BaselineConfig) -> Result<(ServerHandle, Arc<BaselineState>)> {
    let manifest = Arc::new(Manifest::load(&config.artifacts)?);
    let names = config
        .models
        .clone()
        .unwrap_or_else(|| manifest.model_names());
    let mut executors = Vec::new();
    let mut models = Vec::new();
    for name in names {
        if manifest.model(&name).is_none() {
            anyhow::bail!("unknown model '{name}'");
        }
        // One PJRT client per model: the unshared-device layout. Only the
        // fixed bucket is compiled, like a fixed-shape SavedModel.
        let exec = Executor::spawn(
            Arc::clone(&manifest),
            ExecutorOptions {
                models: Some(vec![name.clone()]),
                buckets: Some(vec![config.fixed_batch]),
                warmup: true,
                ..Default::default()
            },
        )
        .with_context(|| format!("spawning client for {name}"))?;
        models.push((
            name,
            exec.handle(),
            Normalizer::new(manifest.norm_mean, manifest.norm_std),
        ));
        executors.push(exec);
    }
    let state = Arc::new(BaselineState {
        manifest,
        models,
        fixed_batch: config.fixed_batch,
        metrics: Arc::new(Metrics::new()),
        _executors: executors,
    });
    let router = build_baseline_router(Arc::clone(&state));
    let handle = Server::spawn(&config.addr, config.http_workers, router.into_handler())?;
    Ok((handle, state))
}

pub fn build_baseline_router(state: Arc<BaselineState>) -> Router {
    let mut router = Router::new();

    let s = Arc::clone(&state);
    router.add("GET", "/healthz", move |_, _| {
        Response::json(
            200,
            &json::obj([
                ("status", Value::from("ok")),
                ("deployment", Value::from("baseline-fixed")),
                ("fixed_batch", Value::from(s.fixed_batch)),
            ]),
        )
    });

    let s = Arc::clone(&state);
    router.add("POST", "/v1/models/:name/predict", move |req, params| {
        let sw = Stopwatch::start();
        s.metrics.inc("requests_total");
        match handle_model_predict(&s, &params["name"], req) {
            Ok(resp) => {
                s.metrics.observe_micros("predict_us", sw.elapsed_micros());
                resp
            }
            Err(e) => {
                s.metrics.inc("errors_total");
                Response::error(422, &format!("{e:#}"))
            }
        }
    });

    router
}

fn handle_model_predict(
    state: &BaselineState,
    name: &str,
    req: &crate::http::Request,
) -> Result<Response> {
    let (_, handle, normalizer) = state
        .models
        .iter()
        .find(|(n, _, _)| n == name)
        .ok_or_else(|| anyhow!("model '{name}' is not deployed"))?;
    // Same streaming fast path as the FlexServe data plane (fall back to
    // the boxed parser on any structural surprise) — the baseline should
    // lose on architecture, not on request parsing.
    let scanned = std::str::from_utf8(&req.body)
        .ok()
        .and_then(crate::coordinator::wire::scan_predict_body);
    let (mut data, body) = match scanned {
        Some((data, rest)) => (data, rest),
        None => {
            let body = req.json_body().map_err(|e| anyhow!("body must be JSON: {e}"))?;
            let data = body
                .get("data")
                .and_then(Value::as_f32_vec)
                .ok_or_else(|| anyhow!("missing numeric 'data'"))?;
            (data, body)
        }
    };
    let elems = state.manifest.sample_elems();
    // Fixed-shape contract: exactly fixed_batch rows, no padding service.
    if data.len() != state.fixed_batch * elems {
        anyhow::bail!(
            "this deployment serves exactly batch={} ({} floats); got {}",
            state.fixed_batch,
            state.fixed_batch * elems,
            data.len()
        );
    }
    // The per-model transform (runs once per model endpoint — the
    // redundancy FlexServe's shared transform removes).
    if !body.get("normalized").and_then(Value::as_bool).unwrap_or(false) {
        normalizer.apply(&mut data);
    }
    let resp = handle.infer(ExecRequest {
        model: name.to_string(),
        batch: state.fixed_batch,
        data: data.into(),
    })?;
    let preds = argmax_rows(&resp.logits, state.manifest.num_classes());
    let classes = json::str_array_raw(
        preds
            .iter()
            .map(|(idx, _)| state.manifest.classes[*idx].as_str()),
    );
    Ok(Response::json(200, &json::obj([("predictions", classes)])))
}

#[cfg(test)]
mod tests {
    // Covered by rust/tests/server_integration.rs (needs artifacts).
}
