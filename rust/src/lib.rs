//! # FlexServe-RS
//!
//! A reproduction of *FlexServe: Deployment of PyTorch Models as Flexible
//! REST Endpoints* (Verenich et al., 2020) as a three-layer Rust + JAX +
//! Pallas stack: JAX/Pallas models are AOT-lowered to XLA HLO at build time
//! (`make artifacts`), and this crate — the Layer-3 coordinator — serves
//! them over REST with multi-model ensembles behind a single endpoint,
//! shared-device execution, dynamic (bucketed) batching, and sensitivity-
//! policy fusion. Python never runs on the request path.
//!
//! Architecture (DESIGN.md has the full inventory):
//!
//! ```text
//!  client ──HTTP──▶ http::Server ──▶ coordinator::api ──▶ coordinator::Ensemble
//!                                          │                    │ sched
//!                                          ▼                    ▼
//!                                   imagepipe (one        runtime::ExecutorPool
//!                                   transform for          (threads owning
//!                                   the whole ensemble)    PjRtClient + HLO
//!                                                          executables)
//! ```
//!
//! The offline build environment provides no tokio/serde/hyper/criterion, so
//! the HTTP server, JSON codec, thread pool, metrics, property-test harness
//! and bench harness are all first-class modules of this crate — which also
//! mirrors the paper's own stack (Flask + Gunicorn sync workers) more
//! faithfully than an async runtime would.

pub mod baseline;
pub mod benchkit;
pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod gateway;
pub mod http;
pub mod imagepipe;
pub mod json;
pub mod mux;
pub mod registry;
pub mod runtime;
pub mod tenant;
pub mod util;
pub mod workload;
