//! E2 (§2.1) — sensitivity-policy sweep over the full policy family.
//!
//! Direct-ensemble variant of `examples/sensitivity.rs` with more policies
//! (adds atleast:2 and accuracy-weighted fusion) and a larger eval set.
//! Regenerates the §2.1 claim: OR-fusion ("any") minimizes false negatives;
//! stricter policies trade sensitivity for specificity — the client picks
//! its point on that curve per request, with no redeployment.

use flexserve::benchkit::{self, artifact_dir};
use flexserve::coordinator::{Confusion, Ensemble, Policy};
use flexserve::runtime::executor::ExecutorOptions;
use flexserve::runtime::{ExecutorPool, Manifest};
use flexserve::util::Prng;
use flexserve::workload;
use std::sync::Arc;

const EVAL_N: usize = 1024;
const TARGET_CLASS: usize = 2; // "cross"

fn main() -> anyhow::Result<()> {
    let manifest = Arc::new(Manifest::load(artifact_dir())?);
    let pool = Arc::new(ExecutorPool::spawn(
        Arc::clone(&manifest),
        ExecutorOptions {
            warmup: true,
            ..Default::default()
        },
        1,
    )?);
    let ensemble = Ensemble::new(pool, Arc::clone(&manifest));
    let models = ensemble.models().to_vec();

    // Accuracy-weighted fusion: weights from the manifest's recorded test
    // accuracies (provenance paying off), threshold = half the total.
    let weights: Vec<f64> = models
        .iter()
        .map(|m| manifest.model(m).unwrap().test_acc)
        .collect();
    let threshold = weights.iter().sum::<f64>() / 2.0;
    let policies: Vec<Policy> = vec![
        Policy::Any,
        Policy::AtLeast(2),
        Policy::Majority,
        Policy::All,
        Policy::Weighted {
            weights,
            threshold,
        },
    ];

    let mut per_model: Vec<Confusion> = vec![Confusion::default(); models.len()];
    let mut per_policy: Vec<Confusion> = vec![Confusion::default(); policies.len()];
    let mut rng = Prng::new(31337);
    let mut served = 0;
    while served < EVAL_N {
        let batch = (EVAL_N - served).min(32);
        let (data, labels) = workload::make_batch(&mut rng, batch);
        let norm = flexserve::imagepipe::Normalizer::new(manifest.norm_mean, manifest.norm_std);
        let normed = norm.applied(&data);
        let out = ensemble.forward(&normed, batch)?;
        let votes = out.votes_for_class(TARGET_CLASS);
        for (row, &lbl) in labels.iter().enumerate() {
            let actual = lbl == TARGET_CLASS;
            for (mi, mv) in votes.iter().enumerate() {
                per_model[mi].record(mv[row], actual);
            }
            let row_votes: Vec<bool> = votes.iter().map(|m| m[row]).collect();
            for (pi, p) in policies.iter().enumerate() {
                per_policy[pi].record(p.fuse(&row_votes)?, actual);
            }
        }
        served += batch;
    }

    let fmt = |c: &Confusion| {
        vec![
            format!("{:.1}%", c.tpr() * 100.0),
            format!("{:.1}%", c.fnr() * 100.0),
            format!("{:.1}%", c.fpr() * 100.0),
            format!("{:.1}%", c.accuracy() * 100.0),
        ]
    };
    let mut rows = Vec::new();
    for (m, c) in models.iter().zip(&per_model) {
        let mut r = vec![format!("model {m}")];
        r.extend(fmt(c));
        rows.push(r);
    }
    for (p, c) in policies.iter().zip(&per_policy) {
        let mut r = vec![format!("policy {p}")];
        r.extend(fmt(c));
        rows.push(r);
    }
    print!(
        "{}",
        benchkit::table(
            &format!(
                "E2 (§2.1): sensitivity policies, target='{}', n={EVAL_N}",
                manifest.classes[TARGET_CLASS]
            ),
            &["detector", "TPR", "FNR", "FPR", "acc"],
            &rows,
        )
    );

    // The §2.1 ordering claims, asserted.
    let fnr: Vec<f64> = per_policy.iter().map(Confusion::fnr).collect();
    assert!(
        fnr[0] <= fnr[1] + 1e-9 && fnr[1] <= fnr[2] + 1e-9 && fnr[2] <= fnr[3] + 1e-9,
        "FNR must be monotone any ≤ atleast:2 ≤ majority ≤ all: {fnr:?}"
    );
    println!("\nFNR monotone across any ≤ atleast:2 ≤ majority ≤ all: OK");
    Ok(())
}
