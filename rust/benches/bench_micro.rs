//! E9 — microbenchmarks of the request-path substrates, used to verify the
//! coordinator is not the bottleneck (§Perf) and to steer the optimization
//! pass: executor dispatch overhead, pad/truncate, JSON codec on predict
//! payloads, softmax/argmax, the normalize transform.

use flexserve::benchkit::{self, artifact_dir};
use flexserve::imagepipe::Normalizer;
use flexserve::json::{self, Value};
use flexserve::runtime::executor::{ExecRequest, ExecutorOptions};
use flexserve::runtime::tensor::{argmax_rows, pad_batch, softmax_rows};
use flexserve::runtime::{Executor, Manifest};
use flexserve::util::Prng;
use flexserve::workload;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let manifest = Arc::new(Manifest::load(artifact_dir())?);
    let elems = manifest.sample_elems();
    let mut rng = Prng::new(5);
    let mut rows = Vec::new();

    // --- device path: exec vs dispatch overhead (mlp is the cheapest).
    let exec = Executor::spawn(
        Arc::clone(&manifest),
        ExecutorOptions {
            models: Some(vec!["mlp".into()]),
            warmup: true,
            ..Default::default()
        },
    )?;
    let handle = exec.handle();
    let (frame, _) = workload::make_batch(&mut rng, 1);
    let mut exec_us_total = 0u64;
    let mut exec_count = 0u64; // warmup iterations also run the closure
    let m = benchkit::measure("mlp b1 roundtrip", 10, 100, || {
        let r = handle
            .infer(ExecRequest {
                model: "mlp".into(),
                batch: 1,
                data: frame.clone().into(),
            })
            .unwrap();
        exec_us_total += r.exec_micros;
        exec_count += 1;
    });
    let mean_rt = m.hist.mean_micros();
    let mean_exec = exec_us_total as f64 / exec_count as f64;
    rows.push(vec![
        "mlp b1: device exec".into(),
        format!("{:.0}us", mean_exec),
        String::new(),
    ]);
    rows.push(vec![
        "mlp b1: dispatch overhead (roundtrip - exec)".into(),
        format!("{:.0}us", mean_rt - mean_exec),
        format!("{:.1}%", (mean_rt - mean_exec) / mean_rt * 100.0),
    ]);

    // --- pure CPU paths.
    let (batch32, _) = workload::make_batch(&mut rng, 32);
    let norm = Normalizer::new(manifest.norm_mean, manifest.norm_std);

    let m = benchkit::measure("normalize b32", 50, 2000, || {
        let mut d = batch32.clone();
        norm.apply(&mut d);
        std::hint::black_box(d);
    });
    rows.push(vec!["normalize b32 (incl clone)".into(), fmt(m.hist.mean_micros()), String::new()]);

    let m = benchkit::measure("pad 3→32", 50, 2000, || {
        std::hint::black_box(pad_batch(&batch32[..3 * elems], 3, 32, elems));
    });
    rows.push(vec!["pad batch 3→32".into(), fmt(m.hist.mean_micros()), String::new()]);

    let logits: Vec<f32> = (0..32 * 4).map(|_| rng.normal() as f32).collect();
    let m = benchkit::measure("softmax+argmax b32", 50, 5000, || {
        let mut l = logits.clone();
        softmax_rows(&mut l, 4);
        std::hint::black_box(argmax_rows(&l, 4));
    });
    rows.push(vec!["softmax+argmax b32x4".into(), fmt(m.hist.mean_micros()), String::new()]);

    // --- JSON codec on a realistic predict body (batch 8).
    let (b8, _) = workload::make_batch(&mut rng, 8);
    let body = json::obj([
        ("data", Value::Arr(b8.iter().map(|&v| Value::from(v)).collect())),
        ("batch", Value::from(8usize)),
    ]);
    let text = json::to_string(&body);
    rows.push(vec!["predict body b8 size".into(), format!("{}B", text.len()), String::new()]);
    let m = benchkit::measure("json parse b8", 50, 1000, || {
        std::hint::black_box(json::parse(&text).unwrap());
    });
    rows.push(vec!["json parse b8 body".into(), fmt(m.hist.mean_micros()), String::new()]);
    let m = benchkit::measure("json ser b8", 50, 1000, || {
        std::hint::black_box(json::to_string(&body));
    });
    rows.push(vec!["json serialize b8 body".into(), fmt(m.hist.mean_micros()), String::new()]);
    let m = benchkit::measure("f32vec b8", 50, 1000, || {
        let v = json::parse(&text).unwrap();
        std::hint::black_box(v.get("data").unwrap().as_f32_vec().unwrap());
    });
    rows.push(vec!["parse + extract f32 vec b8".into(), fmt(m.hist.mean_micros()), String::new()]);

    print!(
        "{}",
        benchkit::table(
            "E9: request-path microbenchmarks",
            &["path", "mean", "note"],
            &rows,
        )
    );
    Ok(())
}

fn fmt(us: f64) -> String {
    if us < 1000.0 {
        format!("{us:.1}us")
    } else {
        format!("{:.2}ms", us / 1000.0)
    }
}
