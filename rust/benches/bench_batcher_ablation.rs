//! E8 (ablation) — scheduler window sweep: the latency/throughput
//! frontier of the FlexServe-RS extension over the paper's pass-through
//! behaviour.
//!
//! 16 closed-loop client threads each send single-frame requests through
//! the scheduler (fixed window, so the sweep measures the knob rather
//! than the adaptive controller) with max_delay ∈ {0, 1, 2, 5, 10} ms.
//! Larger windows coalesce more rows per device batch (higher device
//! efficiency, higher queueing latency). max_delay = 0 is the paper's
//! original behaviour.

use flexserve::benchkit::{self, artifact_dir};
use flexserve::coordinator::{Ensemble, Metrics, SchedConfig, Scheduler, TargetKey};
use flexserve::runtime::executor::ExecutorOptions;
use flexserve::runtime::{ExecutorPool, Manifest};
use flexserve::util::hist::fmt_micros;
use flexserve::util::{Histogram, Prng, Stopwatch};
use flexserve::workload;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

const N_THREADS: usize = 16;
const REQS_PER_THREAD: usize = 25;

fn main() -> anyhow::Result<()> {
    let manifest = Arc::new(Manifest::load(artifact_dir())?);
    let pool = Arc::new(ExecutorPool::spawn(
        Arc::clone(&manifest),
        ExecutorOptions {
            warmup: true,
            ..Default::default()
        },
        1,
    )?);
    let ensemble = Ensemble::new(Arc::clone(&pool), Arc::clone(&manifest));

    let mut rows = Vec::new();
    for delay_ms in [0u64, 1, 2, 5, 10] {
        let batcher = Arc::new(Scheduler::spawn(
            ensemble.clone(),
            SchedConfig {
                max_batch: 32,
                max_delay: Duration::from_millis(delay_ms),
                adaptive: false,
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        )?);

        let hist = Arc::new(Mutex::new(Histogram::new()));
        let coalesced = Arc::new(AtomicU64::new(0));
        let n_batches = Arc::new(AtomicU64::new(0));
        let start = Stopwatch::start();
        let threads: Vec<_> = (0..N_THREADS)
            .map(|t| {
                let batcher = Arc::clone(&batcher);
                let hist = Arc::clone(&hist);
                let coalesced = Arc::clone(&coalesced);
                let n_batches = Arc::clone(&n_batches);
                std::thread::spawn(move || {
                    let mut rng = Prng::new(900 + t as u64);
                    let mut local = Histogram::new();
                    for _ in 0..REQS_PER_THREAD {
                        let (data, _) = workload::make_batch(&mut rng, 1);
                        let sw = Stopwatch::start();
                        let (_, stats) =
                            batcher.submit(TargetKey::Ensemble, data, 1, None).unwrap();
                        local.record(sw.elapsed_micros());
                        coalesced.fetch_add(stats.coalesced_rows as u64, Ordering::Relaxed);
                        n_batches.fetch_add(1, Ordering::Relaxed);
                    }
                    hist.lock().unwrap().merge(&local);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let wall = start.elapsed_secs();
        let n = (N_THREADS * REQS_PER_THREAD) as f64;
        let h = hist.lock().unwrap();
        let mean_coalesced =
            coalesced.load(Ordering::Relaxed) as f64 / n_batches.load(Ordering::Relaxed) as f64;
        rows.push(vec![
            format!("{delay_ms}ms"),
            format!("{:.1}", mean_coalesced),
            fmt_micros(h.p50()),
            fmt_micros(h.p95()),
            fmt_micros(h.p99()),
            format!("{:.1}/s", n / wall),
        ]);
        eprintln!("delay {delay_ms}ms done");
    }
    print!(
        "{}",
        benchkit::table(
            "E8: scheduler window ablation — 16 closed-loop single-frame clients",
            &["max_delay", "avg rows/batch", "p50", "p95", "p99", "req/s"],
            &rows,
        )
    );
    println!("\n(0ms = paper's pass-through; window trades queueing latency for device-batch efficiency)");
    Ok(())
}
