//! E4 (§2.2) — "requiring only one data transformation for all models in
//! the ensemble".
//!
//! FlexServe normalizes the input batch once per request; a per-model-
//! endpoint deployment transforms once per model (and serializes the
//! payload once per model, which we also measure — the client pays N HTTP
//! bodies). Reports the transform + encode cost per request for N = 1..3
//! models at several batch sizes.

use flexserve::benchkit::{self, artifact_dir};
use flexserve::imagepipe::Normalizer;
use flexserve::json::{self, Value};
use flexserve::runtime::Manifest;
use flexserve::util::hist::fmt_micros;
use flexserve::util::Prng;
use flexserve::workload;

const ITERS: u64 = 200;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(artifact_dir())?;
    let norm = Normalizer::new(manifest.norm_mean, manifest.norm_std);
    let mut rng = Prng::new(4);

    let mut rows = Vec::new();
    for batch in [1usize, 8, 32] {
        let (data, _) = workload::make_batch(&mut rng, batch);
        for n_models in 1..=3usize {
            // FlexServe: one transform + one JSON body per request.
            let once = benchkit::measure("once", 20, ITERS, || {
                let mut d = data.clone();
                norm.apply(&mut d);
                let body = json::obj([
                    ("data", Value::Arr(d.iter().map(|&v| Value::from(v)).collect())),
                    ("batch", Value::from(batch)),
                ]);
                std::hint::black_box(json::to_string(&body));
            });
            // Per-model endpoints: transform + body once PER MODEL.
            let per_model = benchkit::measure("per-model", 20, ITERS, || {
                for _ in 0..n_models {
                    let mut d = data.clone();
                    norm.apply(&mut d);
                    let body = json::obj([
                        ("data", Value::Arr(d.iter().map(|&v| Value::from(v)).collect())),
                        ("batch", Value::from(batch)),
                    ]);
                    std::hint::black_box(json::to_string(&body));
                }
            });
            rows.push(vec![
                batch.to_string(),
                n_models.to_string(),
                fmt_micros(once.hist.mean_micros() as u64),
                fmt_micros(per_model.hist.mean_micros() as u64),
                format!(
                    "{:.2}x",
                    per_model.hist.mean_micros() / once.hist.mean_micros()
                ),
            ]);
        }
    }
    print!(
        "{}",
        benchkit::table(
            "E4 (§2.2): transform-once vs transform-per-model (normalize + JSON encode)",
            &["batch", "N models", "once", "per-model", "ratio"],
            &rows,
        )
    );
    println!("\n(expected ratio ≈ N: the per-model layout repeats the work N times)");
    Ok(())
}
