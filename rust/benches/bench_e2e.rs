//! E1 (Fig. 1) — end-to-end architecture under open-loop load sweep.
//!
//! Boots the complete stack (HTTP → router → batcher → ensemble → PJRT)
//! and sweeps the offered Poisson rate, reporting achieved throughput and
//! the latency distribution at each point. The knee of the latency curve
//! is the practical capacity of this testbed.

use flexserve::benchkit;
use flexserve::config::ServeConfig;
use flexserve::coordinator::serve;
use flexserve::http::Client;
use flexserve::json::{self, Value};
use flexserve::util::hist::fmt_micros;
use flexserve::util::{Histogram, Prng, Stopwatch};
use flexserve::workload;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

const SECS: f64 = 6.0;
const N_CLIENTS: usize = 8;

fn main() -> anyhow::Result<()> {
    let mut config = ServeConfig::default();
    config.addr = "127.0.0.1:0".into();
    config.artifacts = benchkit::artifact_dir();
    config.http_workers = 8;
    let (handle, state) = serve(&config)?;
    let addr = handle.addr;

    let mix = [(1usize, 0.45), (2, 0.2), (4, 0.2), (8, 0.1), (16, 0.05)];
    let mut rows = Vec::new();
    for rate in [25.0, 50.0, 100.0, 200.0] {
        let mut rng = Prng::new(rate as u64);
        let schedule = workload::poisson_schedule(&mut rng, rate, SECS, &mix);
        let bodies: Arc<Vec<(std::time::Duration, Vec<u8>)>> = Arc::new(
            schedule
                .iter()
                .map(|a| {
                    let (data, _) = workload::make_batch(&mut rng, a.batch);
                    let body = json::obj([
                        ("data", Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
                        ("batch", Value::from(a.batch)),
                    ]);
                    (a.at, json::to_string(&body).into_bytes())
                })
                .collect(),
        );
        let n_requests = bodies.len();
        let total_rows: usize = schedule.iter().map(|a| a.batch).sum();

        let latencies = Arc::new(Mutex::new(Histogram::new()));
        let errors = Arc::new(AtomicU64::new(0));
        let start = Stopwatch::start();
        let threads: Vec<_> = (0..N_CLIENTS)
            .map(|c| {
                let bodies = Arc::clone(&bodies);
                let latencies = Arc::clone(&latencies);
                let errors = Arc::clone(&errors);
                std::thread::spawn(move || {
                    let mut client = Client::connect(addr).expect("connect");
                    let mut local = Histogram::new();
                    for (at, body) in bodies.iter().skip(c).step_by(N_CLIENTS) {
                        let now = std::time::Duration::from_secs_f64(start.elapsed_secs());
                        if *at > now {
                            std::thread::sleep(*at - now);
                        }
                        let sw = Stopwatch::start();
                        match client.post("/predict", body.clone()) {
                            Ok(r) if r.status == 200 => local.record(sw.elapsed_micros()),
                            _ => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    latencies.lock().unwrap().merge(&local);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let wall = start.elapsed_secs();
        let hist = latencies.lock().unwrap().clone();
        rows.push(vec![
            format!("{rate:.0}"),
            n_requests.to_string(),
            errors.load(Ordering::Relaxed).to_string(),
            fmt_micros(hist.p50()),
            fmt_micros(hist.p95()),
            fmt_micros(hist.p99()),
            format!("{:.1}", n_requests as f64 / wall),
            format!("{:.1}", total_rows as f64 / wall),
        ]);
        eprintln!("rate {rate} done");
    }
    handle.stop();

    print!(
        "{}",
        benchkit::table(
            "E1 (Fig. 1): end-to-end serving, offered-load sweep (Poisson, mixed batch 1-16)",
            &["offered rps", "reqs", "errs", "p50", "p95", "p99", "req/s", "rows/s"],
            &rows,
        )
    );
    let m = state.metrics.render_json();
    println!(
        "\nserver totals: requests={} rows={} errors={}",
        m.path(&["counters", "requests_total"]).and_then(Value::as_u64).unwrap_or(0),
        m.path(&["counters", "rows_total"]).and_then(Value::as_u64).unwrap_or(0),
        m.path(&["counters", "errors_total"]).and_then(Value::as_u64).unwrap_or(0),
    );
    Ok(())
}
