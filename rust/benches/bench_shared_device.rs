//! E3 (§2.2) — one shared device vs one device client per model.
//!
//! For ensemble sizes n = 1..3, builds:
//!   shared    one PJRT client hosting all n models (FlexServe layout);
//!   unshared  n PJRT clients, one model each (one-process-per-model
//!             layout of per-model endpoints).
//!
//! Memory is measured in a FRESH SUBPROCESS per layout (self-exec child
//! mode) so one-time XLA runtime init and allocator reuse don't confound
//! the comparison; latency/throughput are measured in-process on the same
//! workload. Expected shape: unshared memory grows ~n× faster (client +
//! runtime duplicated per model) with no throughput advantage on one
//! physical device.

use flexserve::benchkit::{self, artifact_dir};
use flexserve::coordinator::Ensemble;
use flexserve::runtime::executor::{ExecRequest, ExecutorOptions};
use flexserve::runtime::{Executor, ExecutorPool, Manifest};
use flexserve::util::hist::fmt_micros;
use flexserve::util::Prng;
use flexserve::workload;
use std::sync::Arc;

const BATCH: usize = 8;
const ITERS: u64 = 25;
const CHILD_ENV: &str = "FLEXSERVE_E3_CHILD";

fn main() -> anyhow::Result<()> {
    if let Ok(spec) = std::env::var(CHILD_ENV) {
        return child(&spec);
    }

    let manifest = Arc::new(Manifest::load(artifact_dir())?);
    let all_models = manifest.model_names();
    let mut rng = Prng::new(3);
    let (data, _) = workload::make_batch(&mut rng, BATCH);
    let exe = std::env::current_exe()?;

    // A child that loads nothing: baseline process footprint incl. the
    // one-time XLA/PJRT runtime init, subtracted from every measurement.
    let base_kib = spawn_child(&exe, "none:0")?;

    let mut rows = Vec::new();
    for n in 1..=all_models.len() {
        let models: Vec<String> = all_models[..n].to_vec();

        // --- memory, each layout in a fresh process.
        let shared_mem = spawn_child(&exe, &format!("shared:{n}"))?.saturating_sub(base_kib);
        let unshared_mem = spawn_child(&exe, &format!("unshared:{n}"))?.saturating_sub(base_kib);

        // --- latency/throughput, in-process.
        let pool = Arc::new(ExecutorPool::spawn(
            Arc::clone(&manifest),
            ExecutorOptions {
                models: Some(models.clone()),
                warmup: true,
                ..Default::default()
            },
            1,
        )?);
        let ensemble =
            Ensemble::new(Arc::clone(&pool), Arc::clone(&manifest)).with_models(models.clone())?;
        let shared = benchkit::measure("shared", 3, ITERS, || {
            ensemble.forward(&data, BATCH).unwrap();
        });
        drop(ensemble);
        drop(pool);

        let executors: Vec<Executor> = models
            .iter()
            .map(|m| {
                Executor::spawn(
                    Arc::clone(&manifest),
                    ExecutorOptions {
                        models: Some(vec![m.clone()]),
                        warmup: true,
                        ..Default::default()
                    },
                )
            })
            .collect::<anyhow::Result<_>>()?;
        let handles: Vec<_> = executors.iter().map(|e| e.handle()).collect();
        let unshared = benchkit::measure("unshared", 3, ITERS, || {
            let rxs: Vec<_> = handles
                .iter()
                .zip(&models)
                .map(|(h, m)| {
                    h.infer_async(ExecRequest {
                        model: m.clone(),
                        batch: BATCH,
                        data: data.clone().into(),
                    })
                    .unwrap()
                })
                .collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
        });
        drop(executors);

        rows.push(vec![
            n.to_string(),
            format!("{:.1}MiB", shared_mem as f64 / 1024.0),
            format!("{:.1}MiB", unshared_mem as f64 / 1024.0),
            format!("{:.2}x", unshared_mem as f64 / shared_mem.max(1) as f64),
            fmt_micros(shared.hist.mean_micros() as u64),
            fmt_micros(unshared.hist.mean_micros() as u64),
            format!("{:.1}/s", shared.throughput()),
            format!("{:.1}/s", unshared.throughput()),
        ]);
        eprintln!("n={n} done");
    }
    print!(
        "{}",
        benchkit::table(
            "E3 (§2.2): shared device vs per-model clients — fresh-process memory + ensemble forward (batch 8)",
            &["n", "mem(sh)", "mem(un)", "un/sh", "lat(sh)", "lat(un)", "fwd/s(sh)", "fwd/s(un)"],
            &rows,
        )
    );
    println!(
        "\n(mem = RSS above a no-models child incl. one warmup; un/sh > 1 → unshared layout costs more memory)"
    );
    Ok(())
}

/// Child mode: load the requested layout, print peak RSS (KiB), exit.
fn child(spec: &str) -> anyhow::Result<()> {
    let (layout, n_str) = spec.split_once(':').expect("spec layout:n");
    let n: usize = n_str.parse()?;
    if layout != "none" {
        let manifest = Arc::new(Manifest::load(artifact_dir())?);
        let models: Vec<String> = manifest.model_names()[..n].to_vec();
        let mut keep: Vec<Executor> = Vec::new();
        match layout {
            "shared" => keep.push(Executor::spawn(
                Arc::clone(&manifest),
                ExecutorOptions {
                    models: Some(models),
                    warmup: true,
                    ..Default::default()
                },
            )?),
            "unshared" => {
                for m in models {
                    keep.push(Executor::spawn(
                        Arc::clone(&manifest),
                        ExecutorOptions {
                            models: Some(vec![m]),
                            warmup: true,
                            ..Default::default()
                        },
                    )?);
                }
            }
            other => anyhow::bail!("bad layout {other}"),
        }
        println!("{}", benchkit::rss_kib());
        drop(keep);
    } else {
        // Baseline: init a bare PJRT client only (one-time runtime cost).
        let _client = xla_client_touch()?;
        println!("{}", benchkit::rss_kib());
    }
    Ok(())
}

/// Touch the XLA runtime without loading any model.
fn xla_client_touch() -> anyhow::Result<xla::PjRtClient> {
    Ok(xla::PjRtClient::cpu()?)
}

fn spawn_child(exe: &std::path::Path, spec: &str) -> anyhow::Result<u64> {
    let out = std::process::Command::new(exe)
        .env(CHILD_ENV, spec)
        .env("FLEXSERVE_ARTIFACTS", artifact_dir())
        .output()?;
    anyhow::ensure!(
        out.status.success(),
        "child {spec} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8(out.stdout)?;
    Ok(text
        .lines()
        .last()
        .unwrap_or("0")
        .trim()
        .parse()
        .unwrap_or(0))
}
