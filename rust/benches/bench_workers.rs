//! E7 (§2.2) — "Scaling horizontally to multiple CPU cores is also
//! possible through the use of Gunicorn workers."
//!
//! Sweeps the device-worker count (each worker = one PJRT client with the
//! full ensemble resident, the analogue of one Gunicorn worker process) and
//! measures closed-loop ensemble throughput from 8 concurrent request
//! threads. Expected shape: near-linear scaling until core saturation.

use flexserve::benchkit::{self, artifact_dir};
use flexserve::coordinator::Ensemble;
use flexserve::runtime::executor::ExecutorOptions;
use flexserve::runtime::{ExecutorPool, Manifest};
use flexserve::util::hist::fmt_micros;
use flexserve::util::{Histogram, Prng, Stopwatch};
use flexserve::workload;
use std::sync::{Arc, Mutex};

const BATCH: usize = 4;
const REQS_PER_THREAD: usize = 30;
const N_THREADS: usize = 8;

fn main() -> anyhow::Result<()> {
    let manifest = Arc::new(Manifest::load(artifact_dir())?);
    let mut rng = Prng::new(11);
    let (data, _) = workload::make_batch(&mut rng, BATCH);

    let mut rows = Vec::new();
    let mut base_rate = 0.0;
    for workers in [1usize, 2, 4] {
        let pool = Arc::new(ExecutorPool::spawn(
            Arc::clone(&manifest),
            ExecutorOptions {
                warmup: true,
                ..Default::default()
            },
            workers,
        )?);
        let ensemble = Ensemble::new(Arc::clone(&pool), Arc::clone(&manifest));

        let hist = Arc::new(Mutex::new(Histogram::new()));
        let start = Stopwatch::start();
        let threads: Vec<_> = (0..N_THREADS)
            .map(|_| {
                let ensemble = ensemble.clone();
                let data = data.clone();
                let hist = Arc::clone(&hist);
                std::thread::spawn(move || {
                    let mut local = Histogram::new();
                    for _ in 0..REQS_PER_THREAD {
                        let sw = Stopwatch::start();
                        ensemble.forward(&data, BATCH).unwrap();
                        local.record(sw.elapsed_micros());
                    }
                    hist.lock().unwrap().merge(&local);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let wall = start.elapsed_secs();
        let n = (N_THREADS * REQS_PER_THREAD) as f64;
        let rate = n / wall;
        if workers == 1 {
            base_rate = rate;
        }
        let h = hist.lock().unwrap();
        rows.push(vec![
            workers.to_string(),
            format!("{rate:.1}/s"),
            format!("{:.2}x", rate / base_rate),
            fmt_micros(h.p50()),
            fmt_micros(h.p95()),
        ]);
        eprintln!("workers={workers} done");
    }
    print!(
        "{}",
        benchkit::table(
            "E7 (§2.2): horizontal scaling — device workers (Gunicorn-worker analogue), closed-loop, 8 client threads",
            &["workers", "ensemble fwd/s", "speedup", "p50", "p95"],
            &rows,
        )
    );
    Ok(())
}
