//! E5 (§2.3) — flexible batch sizes vs fixed-batch deployments.
//!
//! Sweeps the client batch size B and compares three serving strategies on
//! identical hardware (one shared device, full 3-model ensemble):
//!
//!   flex      FlexServe bucketed batching: one ensemble forward on the
//!             smallest AOT bucket ≥ B (zero-padded).
//!   fixed-1   TFS-style fixed batch=1 deployment: B sequential forwards.
//!   fixed-32  TFS-style fixed batch=32 deployment: always pad B up to 32.
//!
//! Expected shape: flex ≈ fixed-32 at B=32, strictly better below it
//! (padding tax avoided), and far better than fixed-1 for B > 1
//! (per-call overhead amortized).

use flexserve::benchkit::{self, artifact_dir};
use flexserve::coordinator::Ensemble;
use flexserve::runtime::executor::ExecutorOptions;
use flexserve::runtime::{ExecutorPool, Manifest};
use flexserve::util::hist::fmt_micros;
use flexserve::util::Prng;
use flexserve::workload;
use std::sync::Arc;

const ITERS: u64 = 20;

fn main() -> anyhow::Result<()> {
    let manifest = Arc::new(Manifest::load(artifact_dir())?);
    let pool = Arc::new(ExecutorPool::spawn(
        Arc::clone(&manifest),
        ExecutorOptions {
            warmup: true,
            ..Default::default()
        },
        1,
    )?);
    let ensemble = Ensemble::new(Arc::clone(&pool), Arc::clone(&manifest));
    let mut rng = Prng::new(1);
    let elems = manifest.sample_elems();

    let mut rows = Vec::new();
    for batch in [1usize, 2, 3, 4, 6, 8, 12, 16, 24, 32] {
        let (data, _) = workload::make_batch(&mut rng, batch);

        // flex: one bucketed ensemble forward.
        let flex = benchkit::measure("flex", 3, ITERS, || {
            ensemble.forward(&data, batch).unwrap();
        });

        // fixed-1: B sequential single-frame forwards.
        let fixed1 = benchkit::measure("fixed-1", 1, ITERS.min(10), || {
            for i in 0..batch {
                ensemble
                    .forward(&data[i * elems..(i + 1) * elems], 1)
                    .unwrap();
            }
        });

        // fixed-32: always pad to the largest bucket.
        let mut padded = data.clone();
        padded.resize(32 * elems, 0.0);
        let fixed32 = benchkit::measure("fixed-32", 1, ITERS.min(10), || {
            ensemble.forward(&padded, 32).unwrap();
        });

        let per_img = |mean_us: f64| fmt_micros((mean_us / batch as f64) as u64);
        rows.push(vec![
            batch.to_string(),
            fmt_micros(flex.hist.mean_micros() as u64),
            fmt_micros(fixed1.hist.mean_micros() as u64),
            fmt_micros(fixed32.hist.mean_micros() as u64),
            per_img(flex.hist.mean_micros()),
            format!("{:.2}x", fixed1.hist.mean_micros() / flex.hist.mean_micros()),
            format!("{:.2}x", fixed32.hist.mean_micros() / flex.hist.mean_micros()),
        ]);
        eprintln!("batch {batch} done");
    }
    print!(
        "{}",
        benchkit::table(
            "E5 (§2.3): flexible vs fixed batch — full 3-model ensemble, mean latency per request",
            &["B", "flex", "fixed-1", "fixed-32", "flex/img", "f1/flex", "f32/flex"],
            &rows,
        )
    );
    println!("\n(fN/flex > 1 means FlexServe is faster by that factor)");
    Ok(())
}
