//! Multi-tenant serving-plane integration.
//!
//! Every test boots the REAL `serve` stack device-free (CPU backend over
//! the seeded synthetic artifact set) and drives it through the public
//! wire. Pinned here:
//!
//! * anonymous byte-compat — with no tenants configured the wire is
//!   byte-identical to the keyed stack's answers (tenancy never leaks
//!   into response bodies);
//! * the auth taxonomy across all three protocols (v1 + v2 + mux):
//!   `401 auth.missing_key`, `403 auth.unknown_key`, 200 when keyed;
//! * typed admission sheds: `429 tenant.rate_limited` with `Retry-After`
//!   and `429 tenant.quota_exceeded`, both distinct from
//!   `server.overloaded`;
//! * per-tenant Prometheus series and tenant-attributed audit records;
//! * the fairness pin: a quiet tenant keeps its full goodput while a
//!   noisy tenant offering 10x the load sheds via `tenant.*` only.
//!
//! The stacks share the process-global event bus (serve() rebinds its
//! sink), so every test serializes under one static mutex like the mux
//! suite does.

use flexserve::benchkit::load::{self, LoadConfig};
use flexserve::config::ServeConfig;
use flexserve::coordinator::{serve, SchedConfig};
use flexserve::http::{Client, MuxClient, MuxMsg, Request};
use flexserve::json::{self, Value};
use flexserve::util::Prng;
use flexserve::workload;
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// Serialize every test in this binary: `serve()` rebinds the
/// process-global event sink and subscriber cap at boot.
fn guard() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// Boot a device-free stack (CPU backend over synthetic artifacts),
/// optionally tenanted, with the scheduler `sched` (None = default).
fn boot(
    tenants: Option<&str>,
    sched: Option<SchedConfig>,
) -> (flexserve::http::ServerHandle, std::sync::Arc<flexserve::coordinator::ServerState>) {
    let mut config = ServeConfig::default();
    config.addr = "127.0.0.1:0".into();
    config.artifacts = flexserve::runtime::synth::ensure_artifacts();
    config.http_workers = 4;
    config.device_workers = 1;
    config.warmup = false;
    config.backend = Some("cpu".to_string());
    config.events_metrics_ms = 0; // keep the global bus quiet
    if let Some(spec) = tenants {
        config.tenants =
            flexserve::tenant::parse_tenants(&json::parse(spec).unwrap()).unwrap();
    }
    if let Some(sc) = sched {
        config.scheduler = Some(sc);
    }
    serve(&config).expect("server starts")
}

/// Two keyed tenants: `alpha` (weight 3, unlimited) and `bravo`
/// (weight 1, 1 rps / burst 1 — one request then typed sheds).
const TWO_TENANTS: &str = r#"{
    "alpha": {"key": "alpha-key", "weight": 3},
    "bravo": {"key": "bravo-key", "weight": 1, "rate_rps": 1, "burst": 1}
}"#;

/// A deterministic non-detail v1 predict body (rendering carries no
/// timings, so repeated executions serialize identically).
fn v1_body(seed: u64, batch: usize) -> Value {
    let mut rng = Prng::new(seed);
    let (data, _) = workload::make_batch(&mut rng, batch);
    json::obj([
        ("data", json::f32_array_raw(data.iter().copied())),
        ("batch", Value::from(batch)),
    ])
}

/// POST a v1 predict with optional credentials (header name, value).
fn predict(
    c: &mut Client,
    body: &Value,
    auth: Option<(&str, &str)>,
) -> flexserve::http::Response {
    let mut req = Request::new("POST", "/v1/predict", json::to_string(body).into_bytes());
    req.headers
        .push(("content-type".into(), "application/json".into()));
    if let Some((name, value)) = auth {
        req.headers.push((name.to_string(), value.to_string()));
    }
    c.request(&req).unwrap()
}

/// With no tenants configured the stack is OPEN: unauthenticated requests
/// serve, stray credentials are ignored, and the bytes on the wire are
/// identical to what a keyed stack answers its tenants — tenancy is
/// invisible in response bodies by construction.
#[test]
fn anonymous_mode_is_byte_identical_to_keyed_answers() {
    let _g = guard();
    let (open, _so) = boot(None, None);
    let body = v1_body(42, 3);

    let mut c = Client::connect(open.addr).unwrap();
    let plain = predict(&mut c, &body, None);
    assert_eq!(plain.status, 200, "{}", String::from_utf8_lossy(&plain.body));
    // Open mode ignores stray keys instead of 403ing them.
    let keyed = predict(&mut c, &body, Some(("x-api-key", "whatever")));
    assert_eq!(keyed.status, 200);
    assert_eq!(plain.body, keyed.body, "stray keys must not change the wire");
    open.stop();

    let (closed, _sc) = boot(Some(TWO_TENANTS), None);
    let mut c = Client::connect(closed.addr).unwrap();
    let tenant = predict(&mut c, &body, Some(("authorization", "Bearer alpha-key")));
    assert_eq!(tenant.status, 200, "{}", String::from_utf8_lossy(&tenant.body));
    assert_eq!(
        plain.body, tenant.body,
        "keyed answers must be byte-identical to the open wire"
    );
    closed.stop();
}

/// The auth taxonomy holds on every protocol: v1, v2 (OIP), and the mux
/// wire all answer `401 auth.missing_key` without credentials,
/// `403 auth.unknown_key` for a bad key, and serve both tenants' keys.
#[test]
fn auth_taxonomy_across_v1_v2_and_mux() {
    let _g = guard();
    let (handle, _state) = boot(Some(TWO_TENANTS), None);
    let mut c = Client::connect(handle.addr).unwrap();
    let body = v1_body(7, 2);

    // v1: Bearer and x-api-key are both accepted spellings.
    let resp = predict(&mut c, &body, None);
    assert_eq!(resp.status, 401);
    assert_eq!(load::error_code_of(&resp).as_deref(), Some("auth.missing_key"));
    let resp = predict(&mut c, &body, Some(("x-api-key", "no-such-key")));
    assert_eq!(resp.status, 403);
    assert_eq!(load::error_code_of(&resp).as_deref(), Some("auth.unknown_key"));
    let resp = predict(&mut c, &body, Some(("authorization", "Bearer alpha-key")));
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let resp = predict(&mut c, &body, Some(("x-api-key", "alpha-key")));
    assert_eq!(resp.status, 200);

    // v2: same taxonomy in the OIP error shape ({"error": "code: msg"}).
    let mut rng = Prng::new(7);
    let (data, _) = workload::make_batch(&mut rng, 1);
    let v2_body = json::obj([
        (
            "inputs",
            Value::Arr(vec![json::obj([
                ("name", Value::from("input")),
                ("datatype", Value::from("FP32")),
                (
                    "shape",
                    Value::Arr(vec![
                        Value::from(1usize),
                        Value::from(workload::IMG),
                        Value::from(workload::IMG),
                        Value::from(1usize),
                    ]),
                ),
                ("data", json::f32_array_raw(data.iter().copied())),
            ])]),
        ),
    ]);
    let post_v2 = |c: &mut Client, auth: Option<(&str, &str)>| {
        let mut req = Request::new(
            "POST",
            "/v2/models/_ensemble/infer",
            json::to_string(&v2_body).into_bytes(),
        );
        req.headers
            .push(("content-type".into(), "application/json".into()));
        if let Some((name, value)) = auth {
            req.headers.push((name.to_string(), value.to_string()));
        }
        c.request(&req).unwrap()
    };
    let resp = post_v2(&mut c, None);
    assert_eq!(resp.status, 401);
    assert_eq!(load::error_code_of(&resp).as_deref(), Some("auth.missing_key"));
    let resp = post_v2(&mut c, Some(("authorization", "Bearer nope")));
    assert_eq!(resp.status, 403);
    assert_eq!(load::error_code_of(&resp).as_deref(), Some("auth.unknown_key"));
    let resp = post_v2(&mut c, Some(("x-api-key", "bravo-key")));
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));

    // mux: identity rides per-frame as the payload's `api_key` member —
    // one session can speak for many tenants, and a frame with no
    // credentials sheds with the same taxonomy as HTTP.
    let mut mc = MuxClient::connect(handle.addr).unwrap();
    match mc.call(1, &body).unwrap() {
        MuxMsg::Error { status, code, .. } => {
            assert_eq!((status, code.as_str()), (401, "auth.missing_key"));
        }
        other => panic!("anonymous mux frame must shed typed, got {other:?}"),
    }
    let mut keyed = body.clone();
    if let Value::Obj(fields) = &mut keyed {
        fields.push(("api_key".to_string(), Value::from("alpha-key")));
    }
    match mc.call(2, &keyed).unwrap() {
        MuxMsg::Reply { .. } => {}
        other => panic!("keyed mux frame must serve, got {other:?}"),
    }
    let mut wrong = body.clone();
    if let Value::Obj(fields) = &mut wrong {
        fields.push(("api_key".to_string(), Value::from("stolen")));
    }
    match mc.call(3, &wrong).unwrap() {
        MuxMsg::Error { status, code, .. } => {
            assert_eq!((status, code.as_str()), (403, "auth.unknown_key"));
        }
        other => panic!("bad mux key must shed typed, got {other:?}"),
    }
    handle.stop();
}

/// A tenant over its token-bucket rate sheds `429 tenant.rate_limited`
/// with a `Retry-After` hint — and the shed is its OWN: the other tenant
/// keeps serving, and the code is never the global `server.overloaded`.
#[test]
fn rate_limit_sheds_typed_with_retry_after() {
    let _g = guard();
    let (handle, _state) = boot(Some(TWO_TENANTS), None);
    let mut c = Client::connect(handle.addr).unwrap();
    let body = v1_body(11, 1);

    // bravo has 1 rps / burst 1: five rapid requests must include both a
    // served one (the burst token) and typed sheds, even on a slow box
    // (tokens available over T seconds = 1 + T).
    let mut served = 0u32;
    let mut shed = 0u32;
    for _ in 0..5 {
        let resp = predict(&mut c, &body, Some(("x-api-key", "bravo-key")));
        match resp.status {
            200 => served += 1,
            429 => {
                assert_eq!(
                    load::error_code_of(&resp).as_deref(),
                    Some("tenant.rate_limited"),
                    "tenant sheds must never be server.overloaded"
                );
                let after: u64 = resp
                    .header("retry-after")
                    .expect("tenant 429 must carry Retry-After")
                    .parse()
                    .unwrap();
                assert!(after >= 1, "Retry-After must be at least a second");
                shed += 1;
            }
            other => panic!("unexpected status {other}"),
        }
    }
    assert!(served >= 1, "the burst token must serve");
    assert!(shed >= 1, "the dry bucket must shed");

    // The noisy neighbor's sheds are invisible to alpha.
    let resp = predict(&mut c, &body, Some(("authorization", "Bearer alpha-key")));
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    handle.stop();
}

/// A tenant at its queue-depth quota sheds `429 tenant.quota_exceeded`
/// while its earlier queued work still completes — quota releases ride
/// the dequeue, not the response.
#[test]
fn queue_quota_sheds_typed_while_queued_work_completes() {
    let _g = guard();
    // A wide batching window holds the first request in the queue long
    // enough for the second to observe the occupied quota.
    let (handle, _state) = boot(
        Some(r#"{"solo": {"key": "solo-key", "queue_quota": 1}}"#),
        Some(SchedConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(400),
            adaptive: false,
            ..Default::default()
        }),
    );
    let addr = handle.addr;
    let first = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        predict(&mut c, &v1_body(3, 1), Some(("x-api-key", "solo-key"))).status
    });
    // Land inside the first request's batching window.
    std::thread::sleep(Duration::from_millis(120));
    let mut c = Client::connect(addr).unwrap();
    let resp = predict(&mut c, &v1_body(4, 1), Some(("x-api-key", "solo-key")));
    assert_eq!(resp.status, 429, "{}", String::from_utf8_lossy(&resp.body));
    assert_eq!(
        load::error_code_of(&resp).as_deref(),
        Some("tenant.quota_exceeded")
    );
    assert_eq!(first.join().unwrap(), 200, "queued work must still serve");
    // The quota released at dequeue: the lane admits again.
    let resp = predict(&mut c, &v1_body(5, 1), Some(("x-api-key", "solo-key")));
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    handle.stop();
}

/// Keyed traffic lands in per-tenant Prometheus series, and control-plane
/// writes by a keyed caller are audit-attributed `tenant:<id>`.
#[test]
fn per_tenant_metrics_and_audit_attribution() {
    let _g = guard();
    let (handle, _state) = boot(Some(TWO_TENANTS), None);
    let mut c = Client::connect(handle.addr).unwrap();
    let body = v1_body(23, 1);

    for _ in 0..3 {
        assert_eq!(
            predict(&mut c, &body, Some(("authorization", "Bearer alpha-key"))).status,
            200
        );
    }
    // Drain bravo's burst token, then force at least one typed shed.
    loop {
        let resp = predict(&mut c, &body, Some(("x-api-key", "bravo-key")));
        if resp.status == 429 {
            break;
        }
        assert_eq!(resp.status, 200);
    }

    let text = String::from_utf8(
        c.get("/v1/metrics?format=prometheus").unwrap().body,
    )
    .unwrap();
    for needle in [
        "flexserve_tenant_alpha_requests_total",
        "flexserve_tenant_alpha_predict_us",
        "flexserve_tenant_bravo_requests_total",
        "flexserve_tenant_bravo_shed_total",
    ] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }

    // A keyed PUT /v1/tenants audits as the tenant that drove it.
    let mut req = Request::new(
        "PUT",
        "/v1/tenants",
        json::to_string(&json::parse(TWO_TENANTS).unwrap()).into_bytes(),
    );
    req.headers
        .push(("content-type".into(), "application/json".into()));
    req.headers
        .push(("authorization".into(), "Bearer alpha-key".into()));
    let resp = c.request(&req).unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let doc = resp.json_body().unwrap();
    assert_eq!(doc.get("count").and_then(Value::as_u64), Some(2), "{doc}");

    let audit = c.audit(10).unwrap();
    let attributed = audit
        .get("audit")
        .and_then(Value::as_arr)
        .map(|entries| {
            entries.iter().any(|e| {
                e.get("event").and_then(Value::as_str) == Some("tenants")
                    && e.get("actor").and_then(Value::as_str) == Some("tenant:alpha")
            })
        })
        .unwrap_or(false);
    assert!(attributed, "no tenant-attributed audit record in {audit}");
    handle.stop();
}

/// The fairness pin: `noiz` (weight 1, hard-capped) offers 10x the
/// connections `calm` (weight 3, unlimited) does. calm must keep 100% of
/// its goodput — comfortably over the >=80%-of-weight-share bar — while
/// every one of noiz's sheds is a typed `tenant.*` verdict, never the
/// global `server.overloaded`.
#[test]
fn quiet_tenant_keeps_goodput_under_noisy_overload() {
    let _g = guard();
    let (handle, _state) = boot(
        Some(
            r#"{
                "calm": {"key": "calm", "weight": 3},
                "noiz": {"key": "noiz", "weight": 1, "rate_rps": 5, "burst": 5}
            }"#,
        ),
        None,
    );
    let cfg = LoadConfig {
        addr: handle.addr,
        connections: 11,
        iters: Some(20),
        warmup: 0,
        batch_mix: vec![(1, 1.0)],
        tenant_mix: load::parse_tenant_mix("noiz=10,calm=1").unwrap(),
        seed: 9,
        ..Default::default()
    };
    let report = load::run(&cfg).unwrap();
    let calm = report.tenants.get("calm").expect("calm slice");
    let noiz = report.tenants.get("noiz").expect("noiz slice");

    assert_eq!(
        calm.errors, 0,
        "the quiet tenant must never shed under a noisy neighbor: {:?}",
        calm.error_codes
    );
    assert!(
        calm.ok_requests() as f64 >= 0.8 * 20.0,
        "calm goodput {} below 80% of its share",
        calm.ok_requests()
    );
    assert!(
        noiz.error_codes.contains_key("tenant.rate_limited"),
        "10x offered load over a 5 rps cap must shed: {:?}",
        noiz.error_codes
    );
    assert!(
        noiz.error_codes.keys().all(|code| code.starts_with("tenant.")),
        "noisy-tenant sheds must be tenant.* verdicts, got {:?}",
        noiz.error_codes
    );
    handle.stop();
}
