//! End-to-end integration: HTTP server + coordinator + runtime + real
//! artifacts. One shared server per test binary (device compile is ~6 s).

use flexserve::baseline::{serve_baseline, BaselineConfig};
use flexserve::config::ServeConfig;
use flexserve::coordinator::{serve, BatcherConfig, ServerState};
use flexserve::http::{Client, ServerHandle};
use flexserve::json::{self, Value};
use flexserve::util::Prng;
use flexserve::workload;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

fn artifact_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    dir
}

struct Stack {
    handle: ServerHandle,
    state: Arc<ServerState>,
}

static STACK: OnceLock<Stack> = OnceLock::new();

fn stack() -> &'static Stack {
    STACK.get_or_init(|| {
        let mut config = ServeConfig::default();
        config.addr = "127.0.0.1:0".into();
        config.artifacts = artifact_dir();
        config.http_workers = 4;
        config.device_workers = 1;
        config.batcher = Some(BatcherConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(1),
        });
        let (handle, state) = serve(&config).expect("server starts");
        Stack { handle, state }
    })
}

fn client() -> Client {
    Client::connect(stack().handle.addr).unwrap()
}

fn predict_body(batch: usize, seed: u64) -> Value {
    let mut rng = Prng::new(seed);
    let (data, _) = workload::make_batch(&mut rng, batch);
    json::obj([
        (
            "data",
            Value::Arr(data.iter().map(|&v| Value::from(v)).collect()),
        ),
        ("batch", Value::from(batch)),
    ])
}

#[test]
fn healthz_and_models() {
    let mut c = client();
    let r = c.get("/healthz").unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(
        r.json_body().unwrap().get("status").unwrap().as_str(),
        Some("ok")
    );

    let r = c.get("/models").unwrap();
    assert_eq!(r.status, 200);
    let v = r.json_body().unwrap();
    assert_eq!(v.get("models").unwrap().as_arr().unwrap().len(), 3);
    // Provenance is exposed (the paper's motivating requirement).
    assert!(v.path(&["provenance", "interchange"]).is_some());

    let r = c.get("/models/cnn_m").unwrap();
    assert_eq!(r.status, 200);
    assert!(r.json_body().unwrap().get("test_acc").unwrap().as_f64().unwrap() > 0.5);
    assert_eq!(c.get("/models/nope").unwrap().status, 404);
}

#[test]
fn predict_paper_wire_format() {
    let mut c = client();
    let r = c.post_json("/predict", &predict_body(4, 1)).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    // Paper §2.3: "model_y_i": ["class", ..., "class"] for every model.
    for model in ["cnn_s", "cnn_m", "mlp"] {
        let preds = v
            .get(&format!("model_{model}"))
            .unwrap_or_else(|| panic!("missing model_{model}"))
            .as_arr()
            .unwrap();
        assert_eq!(preds.len(), 4);
        for p in preds {
            let name = p.as_str().unwrap();
            assert!(workload::CLASSES.contains(&name), "{name}");
        }
    }
    // No opt-in fields requested → none present.
    assert!(v.get("ensemble").is_none());
    assert!(v.get("detail").is_none());
}

#[test]
fn predict_all_batch_sizes_including_nonbucket() {
    // §2.3 — any batch size works, bucket-aligned or not, even > max bucket.
    let mut c = client();
    for batch in [1, 2, 3, 5, 7, 8, 13, 32, 40] {
        let r = c.post_json("/predict", &predict_body(batch, batch as u64)).unwrap();
        assert_eq!(r.status, 200, "batch {batch}: {}", String::from_utf8_lossy(&r.body));
        let v = r.json_body().unwrap();
        assert_eq!(
            v.get("model_mlp").unwrap().as_arr().unwrap().len(),
            batch,
            "batch {batch}"
        );
    }
}

#[test]
fn predict_with_policy_fusion() {
    let mut c = client();
    // Build a batch with crisp crosses at rows 0 and 2 (blank row 1).
    let mut rng = Prng::new(33);
    let f_cross1 = workload::make_frame(&mut rng, Some(2));
    let f_blank = workload::make_frame(&mut rng, Some(0));
    let f_cross2 = workload::make_frame(&mut rng, Some(2));
    let mut data = Vec::new();
    for f in [&f_cross1, &f_blank, &f_cross2] {
        data.extend_from_slice(&f.pixels);
    }
    let body = json::obj([
        ("data", Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
        ("batch", Value::from(3usize)),
        ("policy", Value::from("any")),
        ("target", Value::from("cross")),
        ("detail", Value::Bool(true)),
    ]);
    let r = c.post_json("/predict", &body).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    let ens = v.get("ensemble").expect("ensemble fusion present");
    assert_eq!(ens.get("policy").unwrap().as_str(), Some("any"));
    let det = ens.get("detections").unwrap().as_arr().unwrap();
    assert_eq!(det.len(), 3);
    // Detail block present with per-model diagnostics.
    let detail = v.get("detail").expect("detail present");
    assert_eq!(detail.get("batch").unwrap().as_u64(), Some(3));
    assert!(detail.path(&["models", "cnn_m", "exec_us"]).is_some());
}

#[test]
fn predict_model_subset() {
    let mut c = client();
    let mut body = predict_body(2, 9);
    if let Value::Obj(m) = &mut body {
        m.push((
            "models".into(),
            Value::Arr(vec![Value::from("mlp"), Value::from("cnn_s")]),
        ));
    }
    let r = c.post_json("/predict", &body).unwrap();
    assert_eq!(r.status, 200);
    let v = r.json_body().unwrap();
    assert!(v.get("model_mlp").is_some());
    assert!(v.get("model_cnn_s").is_some());
    assert!(v.get("model_cnn_m").is_none(), "subset must exclude cnn_m");
}

#[test]
fn predict_validation_errors() {
    let mut c = client();
    let cases: Vec<(&str, Value)> = vec![
        ("no data", json::obj([("batch", Value::from(1usize))])),
        (
            "short data",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 10])),
                ("batch", Value::from(1usize)),
            ]),
        ),
        (
            "batch 0",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 256])),
                ("batch", Value::from(0usize)),
            ]),
        ),
        (
            "bad policy",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 256])),
                ("policy", Value::from("whenever")),
                ("target", Value::from("cross")),
            ]),
        ),
        (
            "policy without target",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 256])),
                ("policy", Value::from("any")),
            ]),
        ),
        (
            "unknown model",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 256])),
                ("models", Value::Arr(vec![Value::from("resnet152")])),
            ]),
        ),
        (
            "unknown target class",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 256])),
                ("policy", Value::from("any")),
                ("target", Value::from("unicorn")),
            ]),
        ),
    ];
    for (name, body) in cases {
        let r = c.post_json("/predict", &body).unwrap();
        assert_eq!(r.status, 422, "case '{name}' should 422");
        let v = r.json_body().unwrap();
        assert!(v.path(&["error", "message"]).is_some(), "case '{name}'");
    }
    // Non-JSON body → 422 as well.
    let r = c.post("/predict", b"not json".to_vec()).unwrap();
    assert_eq!(r.status, 422);
}

#[test]
fn concurrent_requests_coalesce_in_batcher() {
    // Fire 8 concurrent single-frame requests; the 1 ms batching window
    // should coalesce at least some of them (asserted via metrics).
    let addr = stack().handle.addr;
    let before = stack().state.metrics.counter("rows_total");
    let threads: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let r = c.post_json("/predict", &predict_body(1, 100 + i)).unwrap();
                assert_eq!(r.status, 200);
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let after = stack().state.metrics.counter("rows_total");
    assert_eq!(after - before, 8);
}

#[test]
fn metrics_exposed() {
    let mut c = client();
    let _ = c.post_json("/predict", &predict_body(1, 77)).unwrap();
    let r = c.get("/metrics").unwrap();
    let text = String::from_utf8(r.body.clone()).unwrap();
    assert!(text.contains("flexserve_requests_total"));
    assert!(text.contains("flexserve_predict_us_p99_us"));
    let r = c.get("/metrics?format=json").unwrap();
    let v = r.json_body().unwrap();
    assert!(v.path(&["counters", "requests_total"]).unwrap().as_u64().unwrap() >= 1);
}

#[test]
fn accuracy_on_labelled_workload_matches_manifest() {
    // Serve 200 labelled frames and check each model's serving accuracy is
    // within tolerance of its recorded test accuracy — the end-to-end
    // "numbers are right" check through HTTP + JSON + PJRT.
    let mut c = client();
    let mut rng = Prng::new(4242);
    let n_total = 200usize;
    let mut correct = [0usize; 3];
    let model_names = ["cnn_s", "cnn_m", "mlp"];
    let mut served = 0usize;
    while served < n_total {
        let batch = (n_total - served).min(32);
        let (data, labels) = workload::make_batch(&mut rng, batch);
        let body = json::obj([
            ("data", Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
            ("batch", Value::from(batch)),
        ]);
        let v = c.post_json("/predict", &body).unwrap().json_body().unwrap();
        for (mi, name) in model_names.iter().enumerate() {
            let preds = v.get(&format!("model_{name}")).unwrap().as_arr().unwrap();
            for (p, &lbl) in preds.iter().zip(&labels) {
                if p.as_str().unwrap() == workload::CLASSES[lbl] {
                    correct[mi] += 1;
                }
            }
        }
        served += batch;
    }
    let manifest = &stack().state.manifest;
    for (mi, name) in model_names.iter().enumerate() {
        let acc = correct[mi] as f64 / n_total as f64;
        let expected = manifest.model(name).unwrap().test_acc;
        assert!(
            (acc - expected).abs() < 0.12,
            "{name}: served acc {acc:.3} vs manifest {expected:.3}"
        );
    }
}

#[test]
fn predict_pgm_b64_frames() {
    // §2.3 camera wire format: base64 binary-PGM frames.
    let mut c = client();
    let mut rng = Prng::new(55);
    let frames: Vec<Value> = (0..3)
        .map(|_| {
            let f = workload::make_frame(&mut rng, Some(3));
            let pgm = flexserve::imagepipe::encode_pgm(
                workload::IMG,
                workload::IMG,
                &f.pixels,
            );
            Value::from(flexserve::util::base64::encode(&pgm))
        })
        .collect();
    let body = json::obj([("pgm_b64", Value::Arr(frames))]);
    let r = c.post_json("/predict", &body).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    assert_eq!(v.get("model_cnn_m").unwrap().as_arr().unwrap().len(), 3);

    // Error paths: both inputs, bad base64, wrong dims.
    let both = json::obj([
        ("data", Value::Arr(vec![Value::from(0.0); 256])),
        ("pgm_b64", Value::Arr(vec![Value::from("Zm9v")])),
    ]);
    assert_eq!(c.post_json("/predict", &both).unwrap().status, 422);
    let bad = json::obj([("pgm_b64", Value::Arr(vec![Value::from("!!!")]))]);
    assert_eq!(c.post_json("/predict", &bad).unwrap().status, 422);
    let tiny = flexserve::imagepipe::encode_pgm(2, 2, &[0.0; 4]);
    let wrong = json::obj([(
        "pgm_b64",
        Value::Arr(vec![Value::from(flexserve::util::base64::encode(&tiny))]),
    )]);
    assert_eq!(c.post_json("/predict", &wrong).unwrap().status, 422);
}

// ---------------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------------

#[test]
fn tampered_artifact_fails_provenance_gate() {
    // Copy artifacts, flip one byte in a weight constant, expect the
    // SHA-256 verification to refuse to serve (the paper's provenance
    // argument, enforced).
    let src = artifact_dir();
    let dst = std::env::temp_dir().join("flexserve_tampered");
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(&dst).unwrap();
    for entry in std::fs::read_dir(&src).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
    // Tamper: append junk to one artifact.
    let victim = dst.join("mlp_b1.hlo.txt");
    let mut text = std::fs::read_to_string(&victim).unwrap();
    text.push_str("\n// tampered");
    std::fs::write(&victim, text).unwrap();

    let manifest = flexserve::runtime::Manifest::load(&dst).unwrap();
    let err = manifest.verify_all().unwrap_err();
    assert!(format!("{err:#}").contains("provenance"), "{err:#}");

    // And a server configured with verify_sha must refuse to start.
    let mut config = ServeConfig::default();
    config.addr = "127.0.0.1:0".into();
    config.artifacts = dst.clone();
    config.verify_sha = true;
    assert!(serve(&config).is_err());
    let _ = std::fs::remove_dir_all(&dst);
}

#[test]
fn missing_manifest_is_clear_error() {
    let err = flexserve::runtime::Manifest::load("/nonexistent/nowhere").unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"));
}

// ---------------------------------------------------------------------------
// CLI binary
// ---------------------------------------------------------------------------

#[test]
fn cli_models_and_verify() {
    let bin = env!("CARGO_BIN_EXE_flexserve");
    let out = std::process::Command::new(bin)
        .args(["models", "--artifacts"])
        .arg(artifact_dir())
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = json::parse(std::str::from_utf8(&out.stdout).unwrap()).unwrap();
    assert!(doc.path(&["models", "cnn_m", "test_acc"]).is_some());

    let out = std::process::Command::new(bin)
        .args(["verify", "--artifacts"])
        .arg(artifact_dir())
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("ok: 18 artifacts"));

    // Unknown command exits nonzero with a helpful message.
    let out = std::process::Command::new(bin).arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

// ---------------------------------------------------------------------------
// Baseline (TFS-style) server
// ---------------------------------------------------------------------------

static BASELINE: OnceLock<Mutex<(ServerHandle, Arc<flexserve::baseline::BaselineState>)>> =
    OnceLock::new();

fn baseline_addr() -> std::net::SocketAddr {
    BASELINE
        .get_or_init(|| {
            let config = BaselineConfig {
                addr: "127.0.0.1:0".into(),
                http_workers: 4,
                artifacts: artifact_dir(),
                fixed_batch: 4,
                models: Some(vec!["mlp".into(), "cnn_s".into()]),
            };
            Mutex::new(serve_baseline(&config).expect("baseline starts"))
        })
        .lock()
        .unwrap()
        .0
        .addr
}

#[test]
fn baseline_fixed_batch_contract() {
    let mut c = Client::connect(baseline_addr()).unwrap();
    let mut rng = Prng::new(8);
    let (data, _) = workload::make_batch(&mut rng, 4);
    let body = json::obj([(
        "data",
        Value::Arr(data.iter().map(|&v| Value::from(v)).collect()),
    )]);
    // Exact batch works, per-model endpoint.
    let r = c.post_json("/v1/models/mlp/predict", &body).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    assert_eq!(v.get("predictions").unwrap().as_arr().unwrap().len(), 4);

    // Wrong batch size is REJECTED (the inflexibility FlexServe removes).
    let (small, _) = workload::make_batch(&mut rng, 2);
    let body = json::obj([(
        "data",
        Value::Arr(small.iter().map(|&v| Value::from(v)).collect()),
    )]);
    let r = c.post_json("/v1/models/mlp/predict", &body).unwrap();
    assert_eq!(r.status, 422);

    // Undeployed model → 422 (deployed set was restricted).
    let (d4, _) = workload::make_batch(&mut rng, 4);
    let body = json::obj([(
        "data",
        Value::Arr(d4.iter().map(|&v| Value::from(v)).collect()),
    )]);
    let r = c.post_json("/v1/models/cnn_m/predict", &body).unwrap();
    assert_eq!(r.status, 422);
}
