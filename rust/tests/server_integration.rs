//! End-to-end integration: HTTP server + coordinator + runtime. One
//! shared server per test binary (device compile is ~6 s). Always-on:
//! boots from real artifacts when `make artifacts` produced them, else
//! the synthetic CPU-backend set; only the trained-numerics accuracy
//! check still requires the real zoo.

use flexserve::baseline::{serve_baseline, BaselineConfig};
use flexserve::config::ServeConfig;
use flexserve::coordinator::{serve, SchedConfig, ServerState};
use flexserve::http::{Client, Request, ServerHandle};
use flexserve::json::{self, Value};
use flexserve::util::Prng;
use flexserve::workload;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Real artifacts when `make artifacts` produced them, else the seeded
/// synthetic CPU-backend set — the suite is always-on either way.
fn artifact_dir() -> PathBuf {
    flexserve::runtime::synth::ensure_artifacts()
}

/// Tests that need TRAINED models (real accuracy) skip rather than fail
/// when `make artifacts` has not run; the synthetic fallback is random
/// weights, so its serving accuracy means nothing.
fn has_trained_artifacts() -> bool {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join("manifest.json")
        .exists()
}

struct Stack {
    handle: ServerHandle,
    state: Arc<ServerState>,
}

static STACK: OnceLock<Stack> = OnceLock::new();

fn stack() -> &'static Stack {
    STACK.get_or_init(|| {
        let mut config = ServeConfig::default();
        config.addr = "127.0.0.1:0".into();
        config.artifacts = artifact_dir();
        config.http_workers = 4;
        config.device_workers = 1;
        // Fixed 5 ms window: the coalescing tests need deterministic
        // batching behaviour, not the adaptive ramp.
        config.scheduler = Some(SchedConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(5),
            adaptive: false,
            ..Default::default()
        });
        let (handle, state) = serve(&config).expect("server starts");
        Stack { handle, state }
    })
}

fn client() -> Client {
    Client::connect(stack().handle.addr).unwrap()
}

fn predict_body(batch: usize, seed: u64) -> Value {
    let mut rng = Prng::new(seed);
    let (data, _) = workload::make_batch(&mut rng, batch);
    json::obj([
        (
            "data",
            Value::Arr(data.iter().map(|&v| Value::from(v)).collect()),
        ),
        ("batch", Value::from(batch)),
    ])
}

#[test]
fn healthz_and_models() {
    let mut c = client();
    let r = c.get("/healthz").unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(
        r.json_body().unwrap().get("status").unwrap().as_str(),
        Some("ok")
    );

    let r = c.get("/models").unwrap();
    assert_eq!(r.status, 200);
    let v = r.json_body().unwrap();
    assert_eq!(v.get("models").unwrap().as_arr().unwrap().len(), 3);
    // Provenance is exposed (the paper's motivating requirement).
    assert!(v.path(&["provenance", "interchange"]).is_some());

    let r = c.get("/models/cnn_m").unwrap();
    assert_eq!(r.status, 200);
    assert!(r.json_body().unwrap().get("test_acc").unwrap().as_f64().unwrap() > 0.5);
    assert_eq!(c.get("/models/nope").unwrap().status, 404);
}

#[test]
fn predict_paper_wire_format() {
    let mut c = client();
    let r = c.post_json("/predict", &predict_body(4, 1)).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    // Paper §2.3: "model_y_i": ["class", ..., "class"] for every model.
    for model in ["cnn_s", "cnn_m", "mlp"] {
        let preds = v
            .get(&format!("model_{model}"))
            .unwrap_or_else(|| panic!("missing model_{model}"))
            .as_arr()
            .unwrap();
        assert_eq!(preds.len(), 4);
        for p in preds {
            let name = p.as_str().unwrap();
            assert!(workload::CLASSES.contains(&name), "{name}");
        }
    }
    // No opt-in fields requested → none present.
    assert!(v.get("ensemble").is_none());
    assert!(v.get("detail").is_none());
}

#[test]
fn predict_all_batch_sizes_including_nonbucket() {
    // §2.3 — any batch size works, bucket-aligned or not, even > max bucket.
    let mut c = client();
    for batch in [1, 2, 3, 5, 7, 8, 13, 32, 40] {
        let r = c.post_json("/predict", &predict_body(batch, batch as u64)).unwrap();
        assert_eq!(r.status, 200, "batch {batch}: {}", String::from_utf8_lossy(&r.body));
        let v = r.json_body().unwrap();
        assert_eq!(
            v.get("model_mlp").unwrap().as_arr().unwrap().len(),
            batch,
            "batch {batch}"
        );
    }
}

#[test]
fn predict_with_policy_fusion() {
    let mut c = client();
    // Build a batch with crisp crosses at rows 0 and 2 (blank row 1).
    let mut rng = Prng::new(33);
    let f_cross1 = workload::make_frame(&mut rng, Some(2));
    let f_blank = workload::make_frame(&mut rng, Some(0));
    let f_cross2 = workload::make_frame(&mut rng, Some(2));
    let mut data = Vec::new();
    for f in [&f_cross1, &f_blank, &f_cross2] {
        data.extend_from_slice(&f.pixels);
    }
    let body = json::obj([
        ("data", Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
        ("batch", Value::from(3usize)),
        ("policy", Value::from("any")),
        ("target", Value::from("cross")),
        ("detail", Value::Bool(true)),
    ]);
    let r = c.post_json("/predict", &body).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    let ens = v.get("ensemble").expect("ensemble fusion present");
    assert_eq!(ens.get("policy").unwrap().as_str(), Some("any"));
    let det = ens.get("detections").unwrap().as_arr().unwrap();
    assert_eq!(det.len(), 3);
    // Detail block present with per-model diagnostics.
    let detail = v.get("detail").expect("detail present");
    assert_eq!(detail.get("batch").unwrap().as_u64(), Some(3));
    assert!(detail.path(&["models", "cnn_m", "exec_us"]).is_some());
}

#[test]
fn predict_model_subset() {
    let mut c = client();
    let mut body = predict_body(2, 9);
    if let Value::Obj(m) = &mut body {
        m.push((
            "models".into(),
            Value::Arr(vec![Value::from("mlp"), Value::from("cnn_s")]),
        ));
    }
    let r = c.post_json("/predict", &body).unwrap();
    assert_eq!(r.status, 200);
    let v = r.json_body().unwrap();
    assert!(v.get("model_mlp").is_some());
    assert!(v.get("model_cnn_s").is_some());
    assert!(v.get("model_cnn_m").is_none(), "subset must exclude cnn_m");
}

#[test]
fn predict_validation_errors() {
    let mut c = client();
    let cases: Vec<(&str, Value)> = vec![
        ("no data", json::obj([("batch", Value::from(1usize))])),
        (
            "short data",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 10])),
                ("batch", Value::from(1usize)),
            ]),
        ),
        (
            "batch 0",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 256])),
                ("batch", Value::from(0usize)),
            ]),
        ),
        (
            "bad policy",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 256])),
                ("policy", Value::from("whenever")),
                ("target", Value::from("cross")),
            ]),
        ),
        (
            "policy without target",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 256])),
                ("policy", Value::from("any")),
            ]),
        ),
        (
            "unknown model",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 256])),
                ("models", Value::Arr(vec![Value::from("resnet152")])),
            ]),
        ),
        (
            "unknown target class",
            json::obj([
                ("data", Value::Arr(vec![Value::from(1.0); 256])),
                ("policy", Value::from("any")),
                ("target", Value::from("unicorn")),
            ]),
        ),
    ];
    for (name, body) in cases {
        let r = c.post_json("/predict", &body).unwrap();
        assert_eq!(r.status, 422, "case '{name}' should 422");
        let v = r.json_body().unwrap();
        assert!(v.path(&["error", "message"]).is_some(), "case '{name}'");
    }
    // Non-JSON body → 422 as well.
    let r = c.post("/predict", b"not json".to_vec()).unwrap();
    assert_eq!(r.status, 422);
}

#[test]
fn concurrent_requests_coalesce_in_batcher() {
    // Fire 8 concurrent single-frame requests; the 1 ms batching window
    // should coalesce at least some of them (asserted via metrics).
    let addr = stack().handle.addr;
    let before = stack().state.metrics.counter("rows_total");
    let threads: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let r = c.post_json("/predict", &predict_body(1, 100 + i)).unwrap();
                assert_eq!(r.status, 200);
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let after = stack().state.metrics.counter("rows_total");
    assert_eq!(after - before, 8);
}

/// Fire `n` concurrent POSTs of `body` at `path` and return the max
/// `detail.batching.coalesced_requests` observed across the 200s.
fn max_coalesced(path: &'static str, body: &Value, n: usize) -> u64 {
    let addr = stack().handle.addr;
    let threads: Vec<_> = (0..n)
        .map(|_| {
            let body = body.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let r = c.post_json(path, &body).unwrap();
                assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
                r.json_body()
                    .unwrap()
                    .path(&["detail", "batching", "coalesced_requests"])
                    .expect("batching stats present in detail")
                    .as_u64()
                    .unwrap()
            })
        })
        .collect();
    threads.into_iter().map(|t| t.join().unwrap()).max().unwrap()
}

#[test]
fn single_model_requests_coalesce_in_their_own_queue() {
    // The fast path rides the scheduler now: 16 concurrent same-model
    // requests inside a 5 ms fixed window must share device batches —
    // the seed bypassed batching entirely here.
    let mut body = predict_body(1, 321);
    if let Value::Obj(m) = &mut body {
        m.push(("detail".into(), Value::Bool(true)));
    }
    let max = max_coalesced("/v1/models/cnn_s/predict", &body, 16);
    assert!(max > 1, "no single-model coalescing observed (max {max})");
}

#[test]
fn subset_requests_coalesce_in_their_own_queue() {
    let mut body = predict_body(1, 654);
    if let Value::Obj(m) = &mut body {
        m.push((
            "models".into(),
            Value::Arr(vec![Value::from("cnn_s"), Value::from("mlp")]),
        ));
        m.push(("detail".into(), Value::Bool(true)));
    }
    let max = max_coalesced("/v1/predict", &body, 16);
    assert!(max > 1, "no subset coalescing observed (max {max})");
}

#[test]
fn metrics_exposed() {
    let mut c = client();
    let _ = c.post_json("/predict", &predict_body(1, 77)).unwrap();
    let r = c.get("/metrics").unwrap();
    let text = String::from_utf8(r.body.clone()).unwrap();
    assert!(text.contains("flexserve_requests_total"));
    assert!(text.contains("flexserve_predict_us_p99_us"));
    let r = c.get("/metrics?format=json").unwrap();
    let v = r.json_body().unwrap();
    assert!(v.path(&["counters", "requests_total"]).unwrap().as_u64().unwrap() >= 1);
}

#[test]
fn accuracy_on_labelled_workload_matches_manifest() {
    // Serve 200 labelled frames and check each model's serving accuracy is
    // within tolerance of its recorded test accuracy — the end-to-end
    // "numbers are right" check through HTTP + JSON + PJRT. Trained
    // weights only — the synthetic fallback is random and classifies
    // nothing.
    if !has_trained_artifacts() {
        eprintln!("skipping: trained artifacts missing — run `make artifacts` first");
        return;
    }
    let mut c = client();
    let mut rng = Prng::new(4242);
    let n_total = 200usize;
    let mut correct = [0usize; 3];
    let model_names = ["cnn_s", "cnn_m", "mlp"];
    let mut served = 0usize;
    while served < n_total {
        let batch = (n_total - served).min(32);
        let (data, labels) = workload::make_batch(&mut rng, batch);
        let body = json::obj([
            ("data", Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
            ("batch", Value::from(batch)),
        ]);
        let v = c.post_json("/predict", &body).unwrap().json_body().unwrap();
        for (mi, name) in model_names.iter().enumerate() {
            let preds = v.get(&format!("model_{name}")).unwrap().as_arr().unwrap();
            for (p, &lbl) in preds.iter().zip(&labels) {
                if p.as_str().unwrap() == workload::CLASSES[lbl] {
                    correct[mi] += 1;
                }
            }
        }
        served += batch;
    }
    let manifest = &stack().state.manifest;
    for (mi, name) in model_names.iter().enumerate() {
        let acc = correct[mi] as f64 / n_total as f64;
        let expected = manifest.model(name).unwrap().test_acc;
        assert!(
            (acc - expected).abs() < 0.12,
            "{name}: served acc {acc:.3} vs manifest {expected:.3}"
        );
    }
}

#[test]
fn predict_pgm_b64_frames() {
    // §2.3 camera wire format: base64 binary-PGM frames.
    let mut c = client();
    let mut rng = Prng::new(55);
    let frames: Vec<Value> = (0..3)
        .map(|_| {
            let f = workload::make_frame(&mut rng, Some(3));
            let pgm = flexserve::imagepipe::encode_pgm(
                workload::IMG,
                workload::IMG,
                &f.pixels,
            );
            Value::from(flexserve::util::base64::encode(&pgm))
        })
        .collect();
    let body = json::obj([("pgm_b64", Value::Arr(frames))]);
    let r = c.post_json("/predict", &body).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    assert_eq!(v.get("model_cnn_m").unwrap().as_arr().unwrap().len(), 3);

    // Error paths: both inputs, bad base64, wrong dims.
    let both = json::obj([
        ("data", Value::Arr(vec![Value::from(0.0); 256])),
        ("pgm_b64", Value::Arr(vec![Value::from("Zm9v")])),
    ]);
    assert_eq!(c.post_json("/predict", &both).unwrap().status, 422);
    let bad = json::obj([("pgm_b64", Value::Arr(vec![Value::from("!!!")]))]);
    assert_eq!(c.post_json("/predict", &bad).unwrap().status, 422);
    let tiny = flexserve::imagepipe::encode_pgm(2, 2, &[0.0; 4]);
    let wrong = json::obj([(
        "pgm_b64",
        Value::Arr(vec![Value::from(flexserve::util::base64::encode(&tiny))]),
    )]);
    assert_eq!(c.post_json("/predict", &wrong).unwrap().status, 422);
}

// ---------------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------------

#[test]
fn tampered_artifact_fails_provenance_gate() {
    // Copy artifacts, flip one byte in a weight constant, expect the
    // SHA-256 verification to refuse to serve (the paper's provenance
    // argument, enforced).
    let src = artifact_dir();
    let dst = std::env::temp_dir().join("flexserve_tampered");
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(&dst).unwrap();
    for entry in std::fs::read_dir(&src).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
    // Tamper: append junk bytes to mlp's first bucket artifact — the
    // manifest names it, so this works for the HLO layout and the
    // synthetic weights-sidecar layout alike.
    let manifest = flexserve::runtime::Manifest::load(&dst).unwrap();
    let victim = dst.join(&manifest.model("mlp").unwrap().buckets[0].file);
    let mut bytes = std::fs::read(&victim).unwrap();
    bytes.extend_from_slice(b"\n// tampered");
    std::fs::write(&victim, bytes).unwrap();

    let err = manifest.verify_all().unwrap_err();
    assert!(format!("{err:#}").contains("provenance"), "{err:#}");

    // And a server configured with verify_sha must refuse to start.
    let mut config = ServeConfig::default();
    config.addr = "127.0.0.1:0".into();
    config.artifacts = dst.clone();
    config.verify_sha = true;
    assert!(serve(&config).is_err());
    let _ = std::fs::remove_dir_all(&dst);
}

#[test]
fn missing_manifest_is_clear_error() {
    let err = flexserve::runtime::Manifest::load("/nonexistent/nowhere").unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"));
}

// ---------------------------------------------------------------------------
// CLI binary
// ---------------------------------------------------------------------------

#[test]
fn cli_models_and_verify() {
    let bin = env!("CARGO_BIN_EXE_flexserve");
    let out = std::process::Command::new(bin)
        .args(["models", "--artifacts"])
        .arg(artifact_dir())
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = json::parse(std::str::from_utf8(&out.stdout).unwrap()).unwrap();
    assert!(doc.path(&["models", "cnn_m", "test_acc"]).is_some());

    let out = std::process::Command::new(bin)
        .args(["verify", "--artifacts"])
        .arg(artifact_dir())
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("ok: 18 artifacts"));

    // Unknown command exits nonzero with a helpful message.
    let out = std::process::Command::new(bin).arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

// ---------------------------------------------------------------------------
// Baseline (TFS-style) server
// ---------------------------------------------------------------------------

static BASELINE: OnceLock<Mutex<(ServerHandle, Arc<flexserve::baseline::BaselineState>)>> =
    OnceLock::new();

fn baseline_addr() -> std::net::SocketAddr {
    BASELINE
        .get_or_init(|| {
            let config = BaselineConfig {
                addr: "127.0.0.1:0".into(),
                http_workers: 4,
                artifacts: artifact_dir(),
                fixed_batch: 4,
                models: Some(vec!["mlp".into(), "cnn_s".into()]),
            };
            Mutex::new(serve_baseline(&config).expect("baseline starts"))
        })
        .lock()
        .unwrap()
        .0
        .addr
}

#[test]
fn baseline_fixed_batch_contract() {
    let mut c = Client::connect(baseline_addr()).unwrap();
    let mut rng = Prng::new(8);
    let (data, _) = workload::make_batch(&mut rng, 4);
    let body = json::obj([(
        "data",
        Value::Arr(data.iter().map(|&v| Value::from(v)).collect()),
    )]);
    // Exact batch works, per-model endpoint.
    let r = c.post_json("/v1/models/mlp/predict", &body).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    assert_eq!(v.get("predictions").unwrap().as_arr().unwrap().len(), 4);

    // Wrong batch size is REJECTED (the inflexibility FlexServe removes).
    let (small, _) = workload::make_batch(&mut rng, 2);
    let body = json::obj([(
        "data",
        Value::Arr(small.iter().map(|&v| Value::from(v)).collect()),
    )]);
    let r = c.post_json("/v1/models/mlp/predict", &body).unwrap();
    assert_eq!(r.status, 422);

    // Undeployed model → 422 (deployed set was restricted).
    let (d4, _) = workload::make_batch(&mut rng, 4);
    let body = json::obj([(
        "data",
        Value::Arr(d4.iter().map(|&v| Value::from(v)).collect()),
    )]);
    let r = c.post_json("/v1/models/cnn_m/predict", &body).unwrap();
    assert_eq!(r.status, 422);
}

// ---------------------------------------------------------------------------
// /v1 API: middleware, aliases, error taxonomy, runtime model lifecycle
// ---------------------------------------------------------------------------

fn error_code(r: &flexserve::http::Response) -> String {
    r.json_body()
        .unwrap()
        .path(&["error", "code"])
        .and_then(Value::as_str)
        .unwrap_or("<none>")
        .to_string()
}

#[test]
fn middleware_request_ids_and_route_metrics() {
    let mut c = client();
    // Request-id middleware: generated when absent, echoed when supplied.
    let r = c.get("/healthz").unwrap();
    assert!(r.header("x-request-id").is_some());
    let mut req = Request::new("GET", "/healthz", Vec::new());
    req.headers.push(("x-request-id".into(), "itest-rid-1".into()));
    assert_eq!(c.request(&req).unwrap().header("x-request-id"), Some("itest-rid-1"));

    // Per-route latency metrics + status-class counters via the observer.
    let _ = c.post_json("/v1/predict", &predict_body(1, 41)).unwrap();
    let text = String::from_utf8(c.get("/metrics").unwrap().body).unwrap();
    assert!(text.contains("flexserve_http_requests_total"), "{text}");
    assert!(text.contains("flexserve_http_status_2xx"), "{text}");
    assert!(text.contains("flexserve_route_v1_predict_us_count"), "{text}");
    assert!(text.contains("flexserve_route_healthz_us_count"), "{text}");
}

#[test]
fn v1_aliases_share_handlers_with_legacy_routes() {
    let mut c = client();
    // POST /v1/predict serves the same paper wire format as /predict.
    let v = c
        .post_json("/v1/predict", &predict_body(2, 42))
        .unwrap()
        .json_body()
        .unwrap();
    for model in ["cnn_s", "cnn_m", "mlp"] {
        assert_eq!(
            v.get(&format!("model_{model}")).unwrap().as_arr().unwrap().len(),
            2
        );
    }
    // Introspection aliases return byte-identical bodies.
    for (a, b) in [("/models", "/v1/models"), ("/healthz", "/v1/healthz")] {
        let ra = c.get(a).unwrap();
        let rb = c.get(b).unwrap();
        assert_eq!(ra.status, 200);
        // healthz uptime can tick between the two calls; compare models doc
        // exactly, health by status field.
        if a == "/models" {
            assert_eq!(ra.body, rb.body, "alias {a} vs {b}");
        } else {
            assert_eq!(
                rb.json_body().unwrap().get("status").unwrap().as_str(),
                Some("ok")
            );
        }
    }
    // Percent-encoded model names decode before :name capture.
    let r = c.get("/v1/models/cnn%5Fm").unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(
        r.json_body().unwrap().get("name").unwrap().as_str(),
        Some("cnn_m")
    );
}

#[test]
fn single_model_fast_path() {
    let mut c = client();
    let r = c.post_json("/v1/models/mlp/predict", &predict_body(3, 21)).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    assert_eq!(v.get("model").unwrap().as_str(), Some("mlp"));
    assert_eq!(v.get("predictions").unwrap().as_arr().unwrap().len(), 3);
    assert!(!v.get("params_sha256").unwrap().as_str().unwrap().is_empty());

    // Opt-in detail diagnostics.
    let mut body = predict_body(1, 22);
    if let Value::Obj(m) = &mut body {
        m.push(("detail".into(), Value::Bool(true)));
    }
    let v = c
        .post_json("/v1/models/cnn_s/predict", &body)
        .unwrap()
        .json_body()
        .unwrap();
    assert!(v.path(&["detail", "exec_us"]).is_some());
}

#[test]
fn query_params_override_body_flags() {
    let mut c = client();
    let mut body = predict_body(1, 31);
    if let Value::Obj(m) = &mut body {
        m.push(("models".into(), Value::Arr(vec![Value::from("mlp")])));
        m.push(("policy".into(), Value::from("all")));
        m.push(("target".into(), Value::from("disc")));
    }
    // Non-empty query params override every body flag consistently.
    let r = c
        .post_json("/predict?models=cnn_s&policy=any&target=cross", &body)
        .unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    assert!(v.get("model_cnn_s").is_some(), "query models wins");
    assert!(v.get("model_mlp").is_none(), "body models overridden");
    let ens = v.get("ensemble").unwrap();
    assert_eq!(ens.get("policy").unwrap().as_str(), Some("any"));
    assert_eq!(ens.get("target").unwrap().as_str(), Some("cross"));

    // Empty query values are "unset": the body flags win.
    let r = c
        .post_json("/predict?models=&policy=&target=", &body)
        .unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    assert!(v.get("model_mlp").is_some(), "body models used");
    assert!(v.get("model_cnn_s").is_none());
    let ens = v.get("ensemble").unwrap();
    assert_eq!(ens.get("policy").unwrap().as_str(), Some("all"));
    assert_eq!(ens.get("target").unwrap().as_str(), Some("disc"));
}

/// Separate server for membership-mutating tests so they never race the
/// read-only tests on the shared STACK. Mutating tests serialize on
/// LIFECYCLE_GUARD and restore full membership before releasing it.
static LIFECYCLE: OnceLock<Stack> = OnceLock::new();
static LIFECYCLE_GUARD: Mutex<()> = Mutex::new(());

const ALL_MODELS: [&str; 3] = ["cnn_m", "cnn_s", "mlp"];

fn lifecycle_stack() -> &'static Stack {
    LIFECYCLE.get_or_init(|| {
        let mut config = ServeConfig::default();
        config.addr = "127.0.0.1:0".into();
        config.artifacts = artifact_dir();
        config.http_workers = 4;
        config.device_workers = 1;
        config.warmup = false;
        config.scheduler = Some(SchedConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(1),
            adaptive: false,
            ..Default::default()
        });
        let (handle, state) = serve(&config).expect("lifecycle server starts");
        Stack { handle, state }
    })
}

fn restore_full_membership(c: &mut Client) {
    for m in ALL_MODELS {
        c.load_model(m).expect("restore load");
    }
    c.set_ensemble(&ALL_MODELS).expect("restore membership");
}

#[test]
fn lifecycle_unload_then_predict_then_load() {
    let _guard = LIFECYCLE_GUARD.lock().unwrap();
    let st = lifecycle_stack();
    let mut c = Client::connect(st.handle.addr).unwrap();

    // Unload one model; provenance echoed on the lifecycle response.
    let doc = c.unload_model("cnn_s").unwrap();
    assert_eq!(doc.get("status").unwrap().as_str(), Some("unloaded"));
    assert!(!doc.get("params_sha256").unwrap().as_str().unwrap().is_empty());

    // Ensemble predict serves the REMAINING active models (through the
    // batcher — membership changed between flushes, no restart).
    let r = c.post_json("/v1/predict", &predict_body(2, 5)).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    assert!(v.get("model_cnn_s").is_none(), "unloaded model must not answer");
    assert_eq!(v.get("model_cnn_m").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(v.get("model_mlp").unwrap().as_arr().unwrap().len(), 2);

    // The single-model fast path refuses with a typed 409.
    let r = c.post_json("/v1/models/cnn_s/predict", &predict_body(1, 6)).unwrap();
    assert_eq!(r.status, 409);
    assert_eq!(error_code(&r), "model.not_loaded");

    // Explicit subset predict naming the unloaded model: typed too.
    let mut body = predict_body(1, 7);
    if let Value::Obj(m) = &mut body {
        m.push(("models".into(), Value::Arr(vec![Value::from("cnn_s")])));
    }
    let r = c.post_json("/v1/predict", &body).unwrap();
    assert_eq!(r.status, 409);
    assert_eq!(error_code(&r), "model.not_loaded");

    // Introspection reflects the lifecycle state.
    let v = c.get("/v1/models/cnn_s").unwrap().json_body().unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("unloaded"));
    let v = c.get("/v1/ensemble").unwrap().json_body().unwrap();
    assert_eq!(v.get("active").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(v.get("available").unwrap().as_arr().unwrap().len(), 3);

    // Load restores the model — recompiled + re-activated, no restart.
    let doc = c.load_model("cnn_s").unwrap();
    assert_eq!(doc.get("status").unwrap().as_str(), Some("loaded"));
    assert!(!doc.get("params_sha256").unwrap().as_str().unwrap().is_empty());
    let r = c.post_json("/v1/predict", &predict_body(2, 8)).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    assert_eq!(v.get("model_cnn_s").unwrap().as_arr().unwrap().len(), 2);

    // Double-load is idempotent.
    let doc = c.load_model("cnn_s").unwrap();
    assert_eq!(doc.get("status").unwrap().as_str(), Some("already_loaded"));

    restore_full_membership(&mut c);
}

#[test]
fn put_ensemble_sets_membership_atomically() {
    let _guard = LIFECYCLE_GUARD.lock().unwrap();
    let st = lifecycle_stack();
    let mut c = Client::connect(st.handle.addr).unwrap();

    let doc = c.set_ensemble(&["mlp"]).unwrap();
    assert_eq!(doc.get("active").unwrap().as_arr().unwrap().len(), 1);
    // Provenance echoed per active model.
    let provs = doc.get("models").unwrap().as_arr().unwrap();
    assert_eq!(provs[0].get("name").unwrap().as_str(), Some("mlp"));
    assert!(provs[0].get("params_sha256").is_some());

    let v = c.post_json("/v1/predict", &predict_body(1, 12)).unwrap().json_body().unwrap();
    assert!(v.get("model_mlp").is_some());
    assert!(v.get("model_cnn_s").is_none() && v.get("model_cnn_m").is_none());

    // Members stay loaded even when inactive: fast path still works.
    let r = c.post_json("/v1/models/cnn_s/predict", &predict_body(1, 13)).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = c.get("/v1/models/cnn_s").unwrap().json_body().unwrap();
    assert_eq!(v.get("status").unwrap().as_str(), Some("loaded"));

    // Validation: unknown member, empty set, unloaded member.
    let r = c
        .put_json("/v1/ensemble", &json::obj([("models", Value::Arr(vec![Value::from("nope")]))]))
        .unwrap();
    assert_eq!((r.status, error_code(&r)), (404, "model.unknown".to_string()));
    let r = c
        .put_json("/v1/ensemble", &json::obj([("models", Value::Arr(vec![]))]))
        .unwrap();
    assert_eq!((r.status, error_code(&r)), (422, "bad_input.empty_ensemble".to_string()));
    c.unload_model("cnn_s").unwrap();
    let r = c
        .put_json(
            "/v1/ensemble",
            &json::obj([(
                "models",
                Value::Arr(ALL_MODELS.iter().map(|&m| Value::from(m)).collect()),
            )]),
        )
        .unwrap();
    assert_eq!((r.status, error_code(&r)), (409, "model.not_loaded".to_string()));

    restore_full_membership(&mut c);
}

#[test]
fn error_taxonomy_stable_codes() {
    let _guard = LIFECYCLE_GUARD.lock().unwrap();
    let st = lifecycle_stack();
    let mut c = Client::connect(st.handle.addr).unwrap();

    // Malformed body: 400 on /v1, legacy alias keeps the seed's 422 —
    // same machine-readable code either way.
    let r = c.post("/v1/predict", b"not json".to_vec()).unwrap();
    assert_eq!((r.status, error_code(&r)), (400, "bad_input.malformed_json".to_string()));
    let r = c.post("/predict", b"not json".to_vec()).unwrap();
    assert_eq!((r.status, error_code(&r)), (422, "bad_input.malformed_json".to_string()));

    // Shape mismatch.
    let body = json::obj([
        ("data", Value::Arr(vec![Value::from(1.0); 10])),
        ("batch", Value::from(1usize)),
    ]);
    let r = c.post_json("/v1/predict", &body).unwrap();
    assert_eq!((r.status, error_code(&r)), (422, "bad_input.shape_mismatch".to_string()));

    // Unknown model: subset predict and the per-model routes.
    let body = json::obj([
        ("data", Value::Arr(vec![Value::from(1.0); 256])),
        ("models", Value::Arr(vec![Value::from("resnet152")])),
    ]);
    let r = c.post_json("/v1/predict", &body).unwrap();
    assert_eq!((r.status, error_code(&r)), (404, "model.unknown".to_string()));
    let r = c.post_json("/v1/models/resnet152/predict", &predict_body(1, 14)).unwrap();
    assert_eq!((r.status, error_code(&r)), (404, "model.unknown".to_string()));
    let r = c.post("/v1/models/resnet152/load", Vec::new()).unwrap();
    assert_eq!((r.status, error_code(&r)), (404, "model.unknown".to_string()));

    // Routing errors carry codes too.
    let r = c.get("/v1/nope").unwrap();
    assert_eq!((r.status, error_code(&r)), (404, "route.not_found".to_string()));
    let r = c.get("/v1/predict").unwrap();
    assert_eq!((r.status, error_code(&r)), (405, "route.method_not_allowed".to_string()));

    // Unload everything → predict is a typed 503 ensemble.empty (and the
    // legacy alias flattens the status, not the code).
    for m in ALL_MODELS {
        c.unload_model(m).unwrap();
    }
    let r = c.post_json("/v1/predict", &predict_body(1, 15)).unwrap();
    assert_eq!((r.status, error_code(&r)), (503, "ensemble.empty".to_string()));
    let r = c.post_json("/predict", &predict_body(1, 16)).unwrap();
    assert_eq!((r.status, error_code(&r)), (422, "ensemble.empty".to_string()));

    // Unloading an already-unloaded model is a typed 409.
    let r = c.post("/v1/models/mlp/unload", Vec::new()).unwrap();
    assert_eq!((r.status, error_code(&r)), (409, "model.not_loaded".to_string()));

    restore_full_membership(&mut c);
}
