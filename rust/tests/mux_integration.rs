//! Mux wire + event plane integration tests.
//!
//! The event bus is process-global, so every test in this binary runs
//! under one static mutex (`guard()`) — a subscriber in one test must
//! never observe another test's publishes. Device-free tests drive the
//! REAL `MuxService` session loop over echo executors; the differential
//! test pins mux ≡ v1 byte-identity against the full stack — booting from
//! real artifacts when present, else the synthetic CPU-backend set.

use flexserve::config::ServeConfig;
use flexserve::coordinator::{serve, BreakerConfig, Breakers, Metrics};
use flexserve::http::{Client, MuxClient, MuxMsg, Request, Response, Server, ServerHandle};
use flexserve::json::{self, Value};
use flexserve::mux::{self, codec, MuxOptions, MuxService};
use flexserve::util::Prng;
use flexserve::workload;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// Serialize every test in this binary: the bus is process-global and a
/// concurrent test's publishes would leak into this test's subscribers.
fn guard() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// The process-global metric sink binds once; every test shares it.
fn sink() -> Arc<Metrics> {
    static SINK: OnceLock<Arc<Metrics>> = OnceLock::new();
    let m = SINK.get_or_init(|| Arc::new(Metrics::new()));
    mux::events::set_sink(Arc::clone(m));
    Arc::clone(m)
}

/// Real artifacts when `make artifacts` produced them, else the seeded
/// synthetic CPU-backend set — the differential test is always-on either way.
fn artifact_dir() -> PathBuf {
    flexserve::runtime::synth::ensure_artifacts()
}

/// An echo mux endpoint: replies with the request payload, after an
/// optional payload-controlled delay (`{"delay_ms": N}`).
fn spawn_echo_mux(opts: MuxOptions) -> (ServerHandle, Arc<Metrics>) {
    let metrics = sink();
    let exec: mux::ExecFn = Arc::new(|p: &Value, _auth: &mux::FrameAuth| {
        if let Some(ms) = p.get("delay_ms").and_then(Value::as_u64) {
            std::thread::sleep(Duration::from_millis(ms));
        }
        Ok(p.clone())
    });
    let svc = MuxService::new(exec, Arc::clone(&metrics), opts);
    let m2 = Arc::clone(&metrics);
    let handle = Server::spawn(
        "127.0.0.1:0",
        2,
        Arc::new(move |req: &Request| {
            if req.method == "POST" && req.path == "/v1/mux" {
                return svc.takeover_response(mux::FrameAuth::from_request(req));
            }
            if req.method == "GET" && req.path == "/v1/events" {
                return mux::events_response(req, Arc::clone(&m2), 8);
            }
            Response::coded_error(404, "route.not_found", "mux test server")
        }),
    )
    .unwrap();
    (handle, metrics)
}

/// Out-of-order interleaving by correlation id: the first-sent request is
/// the slowest, so its reply arrives after later-sent ids' replies —
/// demuxed correctly by id, on one connection.
#[test]
fn responses_interleave_out_of_order_by_correlation_id() {
    let _g = guard();
    let (handle, _) = spawn_echo_mux(MuxOptions::default());
    let mut c = MuxClient::connect(handle.addr).unwrap();

    c.request(10, &json::obj([("i", Value::from(10u64)), ("delay_ms", Value::from(250u64))]))
        .unwrap();
    c.request(11, &json::obj([("i", Value::from(11u64))])).unwrap();
    c.request(12, &json::obj([("i", Value::from(12u64))])).unwrap();

    let mut order = Vec::new();
    while order.len() < 3 {
        match c.next().unwrap() {
            MuxMsg::Reply { id, value, .. } => {
                assert_eq!(
                    value.get("i").and_then(Value::as_u64),
                    Some(id),
                    "payload must round-trip its own correlation id"
                );
                order.push(id);
            }
            other => panic!("unexpected message: {other:?}"),
        }
    }
    assert_eq!(
        order.last(),
        Some(&10),
        "the slow first-sent id must complete last: {order:?}"
    );
    assert_ne!(order, vec![10, 11, 12], "no interleaving observed");
    handle.stop();
}

/// A correlation id already in flight is refused with the typed
/// `mux.duplicate_id` envelope; the original request still completes.
#[test]
fn duplicate_in_flight_id_is_refused_typed() {
    let _g = guard();
    let (handle, _) = spawn_echo_mux(MuxOptions::default());
    let mut c = MuxClient::connect(handle.addr).unwrap();

    c.request(7, &json::obj([("i", Value::from(7u64)), ("delay_ms", Value::from(200u64))]))
        .unwrap();
    c.request(7, &json::obj([("i", Value::from(7u64))])).unwrap();

    // First terminal answer for id 7 is the duplicate refusal...
    match c.wait_for(7).unwrap() {
        MuxMsg::Error { status, code, .. } => {
            assert_eq!((status, code.as_str()), (400, "mux.duplicate_id"));
        }
        other => panic!("expected duplicate_id error, got {other:?}"),
    }
    // ...and the original execution still answers.
    match c.wait_for(7).unwrap() {
        MuxMsg::Reply { value, .. } => {
            assert_eq!(value.get("i").and_then(Value::as_u64), Some(7));
        }
        other => panic!("expected the original reply, got {other:?}"),
    }
    handle.stop();
}

/// Past the per-connection in-flight cap, request frames shed with the
/// same `429 server.overloaded` envelope HTTP uses.
#[test]
fn in_flight_cap_sheds_with_http_taxonomy() {
    let _g = guard();
    let (handle, _) = spawn_echo_mux(MuxOptions {
        max_inflight: 2,
        ..MuxOptions::default()
    });
    let mut c = MuxClient::connect(handle.addr).unwrap();

    c.request(1, &json::obj([("delay_ms", Value::from(300u64))])).unwrap();
    c.request(2, &json::obj([("delay_ms", Value::from(300u64))])).unwrap();
    c.request(3, &json::obj([("i", Value::from(3u64))])).unwrap();

    match c.wait_for(3).unwrap() {
        MuxMsg::Error { status, code, .. } => {
            assert_eq!((status, code.as_str()), (429, "server.overloaded"));
        }
        other => panic!("expected overload shed, got {other:?}"),
    }
    // The two admitted requests still finish.
    assert!(c.wait_for(1).unwrap().is_terminal());
    assert!(c.wait_for(2).unwrap().is_terminal());
    handle.stop();
}

/// Protocol violations on the raw wire: a server→client kind sent inbound
/// answers a typed `mux.bad_frame`; an unparseable length header answers
/// one error frame and closes the session.
#[test]
fn protocol_violations_answer_typed_bad_frame() {
    let _g = guard();
    let (handle, _) = spawn_echo_mux(MuxOptions::default());

    let read_head = |reader: &mut BufReader<TcpStream>| {
        loop {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0, "head truncated");
            if line.trim_end_matches(['\r', '\n']).is_empty() {
                break;
            }
        }
    };
    let next_frame = |reader: &mut BufReader<TcpStream>,
                      dec: &mut codec::FrameDecoder|
     -> Option<codec::Frame> {
        let mut buf = [0u8; 4096];
        loop {
            if let Some(f) = dec.next_frame().unwrap() {
                return Some(f);
            }
            let n = reader.read(&mut buf).unwrap();
            if n == 0 {
                return None;
            }
            dec.push(&buf[..n]);
        }
    };

    // Inbound `event` kind → typed refusal, session stays up.
    let stream = TcpStream::connect(handle.addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut reader = BufReader::new(stream);
    let head = format!(
        "POST /v1/mux HTTP/1.1\r\nhost: {}\r\ncontent-length: 0\r\n\r\n",
        handle.addr
    );
    {
        let mut w: &TcpStream = reader.get_ref();
        w.write_all(head.as_bytes()).unwrap();
    }
    read_head(&mut reader);
    let mut dec = codec::FrameDecoder::new();
    {
        let mut w: &TcpStream = reader.get_ref();
        w.write_all(&codec::Frame::new(3, codec::FrameKind::Event, Value::Null).encode())
            .unwrap();
    }
    let f = next_frame(&mut reader, &mut dec).expect("an error frame");
    assert_eq!((f.id, f.kind), (3, codec::FrameKind::Error));
    assert_eq!(
        f.payload.path(&["error", "code"]).and_then(Value::as_str),
        Some("mux.bad_frame")
    );
    // The session survived the typed refusal: a normal request still works.
    {
        let mut w: &TcpStream = reader.get_ref();
        w.write_all(&codec::Frame::new(4, codec::FrameKind::Request, Value::Null).encode())
            .unwrap();
    }
    let f = next_frame(&mut reader, &mut dec).expect("a reply");
    assert_eq!((f.id, f.kind), (4, codec::FrameKind::Response));

    // Garbage framing → one error frame (id 0), then the session closes.
    {
        let mut w: &TcpStream = reader.get_ref();
        w.write_all(b"not-a-length\n").unwrap();
    }
    let f = next_frame(&mut reader, &mut dec).expect("framing error frame");
    assert_eq!((f.id, f.kind), (0, codec::FrameKind::Error));
    assert_eq!(
        f.payload.path(&["error", "code"]).and_then(Value::as_str),
        Some("mux.bad_frame")
    );
    assert!(
        next_frame(&mut reader, &mut dec).is_none(),
        "unsynchronized session must close"
    );
    handle.stop();
}

/// A slow mux subscriber loses oldest-first, sees a `lagged` marker frame
/// with the dropped count, and the bus's hot path never blocks (the burst
/// publish completes instantly).
#[test]
fn slow_subscriber_sees_lagged_marker_and_dropped_counter() {
    let _g = guard();
    let metrics = sink();
    let (handle, _) = spawn_echo_mux(MuxOptions {
        event_buffer: 4,
        ..MuxOptions::default()
    });
    let mut c = MuxClient::connect(handle.addr).unwrap();
    c.subscribe(900, &["sched"]).unwrap();
    assert!(matches!(c.wait_for(900).unwrap(), MuxMsg::Reply { .. }));

    // Publish far faster than the forwarder can serialize + write: the
    // cap-4 queue must overrun and drop oldest-first.
    let dropped_before = metrics.counter("events_dropped_total");
    for i in 0..200u64 {
        mux::events::publish(
            mux::events::TOPIC_SCHED,
            json::obj([("burst", Value::from(i))]),
        );
    }
    let mut lagged_dropped = 0u64;
    let mut events_seen = 0u64;
    let mut last_burst: Option<u64> = None;
    loop {
        match c.next().unwrap() {
            MuxMsg::Lagged { id, dropped } => {
                assert_eq!(id, 900);
                lagged_dropped += dropped;
            }
            MuxMsg::Event { id, doc } => {
                assert_eq!(id, 900);
                let b = doc.path(&["data", "burst"]).and_then(Value::as_u64).unwrap();
                if let Some(prev) = last_burst {
                    assert!(b > prev, "events must stay in publish order");
                }
                last_burst = Some(b);
                events_seen += 1;
                if b == 199 {
                    break; // the newest event survived the overrun
                }
            }
            other => panic!("unexpected message: {other:?}"),
        }
    }
    assert!(lagged_dropped > 0, "cap-4 queue under a 200-burst must lag");
    assert_eq!(
        lagged_dropped + events_seen,
        200,
        "dropped + delivered must account for every publish"
    );
    assert!(
        metrics.counter("events_dropped_total") >= dropped_before + lagged_dropped,
        "per-subscriber drops must land in events_dropped_total"
    );
    handle.stop();
}

/// Open a `GET /v1/events` NDJSON stream and return its buffered reader
/// with the response head already consumed.
fn open_event_stream(addr: std::net::SocketAddr, topics: &str) -> BufReader<TcpStream> {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    let mut reader = BufReader::new(stream);
    {
        let head = format!("GET /v1/events?topics={topics} HTTP/1.1\r\nhost: {addr}\r\n\r\n");
        let mut w: &TcpStream = reader.get_ref();
        w.write_all(head.as_bytes()).unwrap();
    }
    let mut status = String::new();
    assert!(reader.read_line(&mut status).unwrap() > 0);
    assert!(status.contains("200"), "events stream refused: {status}");
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "head truncated");
        if line.trim_end_matches(['\r', '\n']).is_empty() {
            break;
        }
    }
    // The subscriber registers inside the takeover just after the head;
    // give it a beat so the next publish can't race past it.
    std::thread::sleep(Duration::from_millis(100));
    reader
}

/// Read NDJSON lines until a non-ping event document arrives.
fn next_event(reader: &mut BufReader<TcpStream>) -> Value {
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "stream closed early");
        let doc = json::parse(line.trim()).unwrap();
        if doc.get("ping").is_none() {
            return doc;
        }
    }
}

/// A circuit-breaker trip publishes onto the bus and appears on the plain
/// `GET /v1/events` stream with topic `breaker`.
#[test]
fn breaker_trip_appears_on_event_stream() {
    let _g = guard();
    let metrics = sink();
    let (handle, _) = spawn_echo_mux(MuxOptions::default());
    let mut reader = open_event_stream(handle.addr, "breaker");

    let breakers = Breakers::new(
        BreakerConfig {
            fail_threshold: 2,
            cooldown: Duration::from_millis(200),
        },
        Arc::clone(&metrics),
    );
    let key = Breakers::key("echo", 1);
    breakers.record(&key, false);
    breakers.record(&key, false); // second failure trips the breaker

    let doc = next_event(&mut reader);
    assert_eq!(doc.get("topic").and_then(Value::as_str), Some("breaker"));
    assert_eq!(
        doc.path(&["data", "state"]).and_then(Value::as_str),
        Some("open"),
        "trip event: {doc}"
    );
    assert_eq!(
        doc.path(&["data", "key"]).and_then(Value::as_str),
        Some(key.as_str())
    );
    handle.stop();
}

/// A registry promote (the real state machine, synthetic store) surfaces
/// on `GET /v1/events` within one flush, through the audit → bus hook.
#[test]
fn registry_promote_surfaces_on_event_stream() {
    use flexserve::registry::{Guardrails, Registry, RegistryConfig, Store};

    let _g = guard();
    let metrics = sink();
    let (handle, _) = spawn_echo_mux(MuxOptions::default());
    let mut reader = open_event_stream(handle.addr, "registry");

    let registry = Registry::new(
        Store::synthetic(&[("echo", 2)]),
        RegistryConfig {
            audit_log: None,
            guardrails: Guardrails {
                max_error_rate: 0.5,
                max_p95_us: 0,
                min_samples: 10,
            },
        },
        Arc::clone(&metrics),
    )
    .unwrap();
    let body = json::obj([
        ("mode", Value::from("canary")),
        ("version", Value::from(2u64)),
        ("percent", Value::from(25u64)),
    ]);
    registry.apply_rollout("echo", &body, "test", &|_| true).unwrap();
    let doc = next_event(&mut reader);
    assert_eq!(doc.get("topic").and_then(Value::as_str), Some("registry"));
    assert_eq!(doc.path(&["data", "event"]).and_then(Value::as_str), Some("canary"));

    registry.promote("echo", "test").unwrap();
    let doc = next_event(&mut reader);
    assert_eq!(
        doc.path(&["data", "event"]).and_then(Value::as_str),
        Some("promote"),
        "promote must surface within one flush: {doc}"
    );
    assert_eq!(doc.path(&["data", "model"]).and_then(Value::as_str), Some("echo"));
    assert!(doc.get("seq").and_then(Value::as_u64).is_some(), "events carry seq");
    handle.stop();
}

/// The differential contract (artifact-gated): the same predict payload
/// sent as a mux `request` frame and as `POST /v1/predict` yields
/// BYTE-IDENTICAL response bytes. `mux_chunk_bytes` is forced tiny so the
/// reply streams as many chunk frames — reassembly must reproduce the
/// exact bytes HTTP wrote, proving mux ≡ v1 by construction, chunking
/// included.
#[test]
fn mux_request_matches_v1_predict_byte_for_byte() {
    let _g = guard();
    let mut config = ServeConfig::default();
    config.addr = "127.0.0.1:0".into();
    config.artifacts = artifact_dir();
    config.http_workers = 4;
    config.device_workers = 1;
    config.mux_chunk_bytes = 64; // force the chunked path
    config.events_metrics_ms = 0; // keep the bus quiet for other tests
    let (handle, _state) = serve(&config).expect("server starts");

    // A deterministic non-detail body: rendering carries no timings, so
    // repeated executions serialize identically.
    let mut rng = Prng::new(42);
    let (data, _) = workload::make_batch(&mut rng, 3);
    let body = json::obj([
        (
            "data",
            Value::Arr(data.iter().map(|&v| Value::from(v)).collect()),
        ),
        ("batch", Value::from(3u64)),
    ]);

    let mut http = Client::connect(handle.addr).unwrap();
    let resp = http.post_json("/v1/predict", &body).unwrap();
    assert_eq!(resp.status, 200, "{:?}", resp.json_body());
    let http_bytes = resp.body.clone();

    let mut mc = MuxClient::connect(handle.addr).unwrap();
    match mc.call(1, &body).unwrap() {
        MuxMsg::Reply { raw, .. } => {
            assert!(
                raw.len() > 64,
                "response must exceed the chunk bound to exercise reassembly"
            );
            assert_eq!(
                raw.as_bytes(),
                &http_bytes[..],
                "mux reply must be byte-identical to POST /v1/predict"
            );
        }
        other => panic!("mux predict failed: {other:?}"),
    }

    // And the error taxonomy rides the wire unchanged: a malformed
    // payload answers the same envelope shape HTTP returns.
    match mc.call(2, &json::obj([("nonsense", Value::from(true))])).unwrap() {
        MuxMsg::Error { status, code, .. } => {
            assert_eq!(status, 422, "code {code}");
        }
        other => panic!("expected a typed error, got {other:?}"),
    }
    handle.stop();
}
