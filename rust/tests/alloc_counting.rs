//! Steady-state zero-allocation pin for the pure-Rust backends.
//!
//! A counting allocator wraps `System`; after one warm-up flush has
//! populated the arena's shelves and scratch free list, repeated
//! `Backend::run` calls on the CPU and quant paths must perform **zero**
//! heap allocations — the property the `BufferArena` exists to provide.
//!
//! Single `#[test]` on purpose: the counter is process-global, so a
//! second test thread allocating during the measured window would
//! produce false positives.

use flexserve::runtime::backend::{
    Act, Backend, CpuBackend, CpuWorkers, Layer, ModelGraph, QuantBackend, QuantModel,
};
use flexserve::runtime::BufferArena;
use flexserve::util::Prng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct Counting;

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

/// A 2-layer MLP big enough that the first layer clears the CPU
/// backend's inline threshold (8 x 64 x 96 = 49152 MACs), so the
/// parallel fork/join path is inside the measured window too.
fn graph() -> Arc<ModelGraph> {
    let mut prng = Prng::new(0xA110C);
    let dims = [64usize, 96, 8];
    let mut layers = Vec::new();
    let mut store = Vec::new();
    for w in dims.windows(2) {
        let (i, o) = (w[0], w[1]);
        let w_off = store.len();
        for _ in 0..i * o {
            store.push((prng.normal() as f32) / (i as f32).sqrt());
        }
        let b_off = store.len();
        for _ in 0..o {
            store.push(prng.normal() as f32 * 0.1);
        }
        layers.push(Layer {
            in_dim: i,
            out_dim: o,
            act: Act::Relu,
            w_off,
            b_off,
        });
    }
    layers.last_mut().unwrap().act = Act::Linear;
    Arc::new(ModelGraph::new(layers, store.into()).unwrap())
}

fn measure_steady_state(be: &mut dyn Backend, feed: &[f32], arena: &mut BufferArena) -> u64 {
    // Warm-up: first flushes populate the arena (scratch capacities, the
    // output shelf) and fault in any lazy thread-local state.
    for _ in 0..3 {
        let out = be.run(feed, arena).unwrap();
        drop(out); // release the shelf buffer before the next checkout
    }
    let before = allocs();
    for _ in 0..10 {
        let out = be.run(feed, arena).unwrap();
        drop(out);
    }
    allocs() - before
}

#[test]
fn steady_state_flush_allocates_nothing() {
    let g = graph();
    let bucket = 8usize;
    let mut prng = Prng::new(7);
    let feed: Vec<f32> = (0..bucket * g.in_dim).map(|_| prng.normal() as f32).collect();

    // Sanity: the counter sees ordinary allocation traffic.
    let before = allocs();
    let probe = vec![0u8; 4096];
    assert!(allocs() > before, "counting allocator is not installed");
    drop(probe);

    let mut arena = BufferArena::new(0);

    // CPU path, multi-worker so the epoch-barrier dispatch is measured.
    let workers = Arc::new(CpuWorkers::new(3));
    let mut cpu = CpuBackend::new(Arc::clone(&g), bucket, workers);
    let cpu_allocs = measure_steady_state(&mut cpu, &feed, &mut arena);
    assert_eq!(
        cpu_allocs, 0,
        "cpu backend allocated {cpu_allocs} times across 10 steady-state flushes"
    );

    // Quant path shares the same arena (as it does on a device thread).
    let qm = Arc::new(QuantModel::from_graph(&g));
    let mut quant = QuantBackend::new(qm, bucket);
    let quant_allocs = measure_steady_state(&mut quant, &feed, &mut arena);
    assert_eq!(
        quant_allocs, 0,
        "quant backend allocated {quant_allocs} times across 10 steady-state flushes"
    );
}
