//! Overload / backpressure integration: a live server with a TINY queue
//! cap and a long fixed window, so admission control, deadlines, and
//! drain-on-shutdown are deterministic. Always-on: boots from real
//! artifacts when present, else the synthetic CPU-backend set; tests
//! share one server and serialize on a guard because each one
//! manipulates the global queue state.

use flexserve::config::ServeConfig;
use flexserve::coordinator::{serve, ApiError, Metrics, SchedConfig, Scheduler, ServerState, TargetKey};
use flexserve::http::{Client, ServerHandle};
use flexserve::json::{self, Value};
use flexserve::util::Prng;
use flexserve::workload;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Real artifacts when `make artifacts` produced them, else the seeded
/// synthetic CPU-backend set — this suite is always-on either way.
fn artifact_dir() -> PathBuf {
    flexserve::runtime::synth::ensure_artifacts()
}

struct Stack {
    handle: ServerHandle,
    state: Arc<ServerState>,
}

static STACK: OnceLock<Stack> = OnceLock::new();
/// Every test here fills/drains the shared queues — strictly one at a time.
static GUARD: Mutex<()> = Mutex::new(());

/// Long fixed window + 2-slot queue: requests stay queued long enough to
/// observe admission decisions deterministically.
const WINDOW: Duration = Duration::from_millis(800);

fn stack() -> &'static Stack {
    STACK.get_or_init(|| {
        let mut config = ServeConfig::default();
        config.addr = "127.0.0.1:0".into();
        config.artifacts = artifact_dir();
        config.http_workers = 8;
        config.device_workers = 1;
        config.warmup = false;
        config.models = Some(vec!["mlp".to_string()]); // one model: fast compile
        config.scheduler = Some(SchedConfig {
            max_batch: 32,
            max_delay: WINDOW,
            queue_cap: 2,
            deadline: None,
            adaptive: false,
            ..Default::default()
        });
        let (handle, state) = serve(&config).expect("overload server starts");
        Stack { handle, state }
    })
}

fn predict_body(batch: usize, seed: u64) -> Value {
    let mut rng = Prng::new(seed);
    let (data, _) = workload::make_batch(&mut rng, batch);
    json::obj([
        ("data", json::f32_array_raw(data.iter().copied())),
        ("batch", Value::from(batch)),
    ])
}

fn error_code(v: &Value) -> &str {
    v.path(&["error", "code"]).and_then(Value::as_str).unwrap_or("")
}

/// Park two requests in the ensemble queue (fills the 2-slot cap) and run
/// `probe` while they wait; both parked requests must still succeed.
fn with_full_queue(probe: impl FnOnce()) {
    let addr = stack().handle.addr;
    let occupants: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.post_json("/v1/predict", &predict_body(1, 10 + i)).unwrap()
            })
        })
        .collect();
    // Let both occupants enqueue (the window holds them for 800 ms).
    std::thread::sleep(Duration::from_millis(100));
    probe();
    for t in occupants {
        let r = t.join().unwrap();
        assert_eq!(
            r.status,
            200,
            "queued request must drain OK: {}",
            String::from_utf8_lossy(&r.body)
        );
    }
}

#[test]
fn full_queue_sheds_429_with_retry_after_on_both_protocols() {
    let _guard = GUARD.lock().unwrap();
    let st = stack();
    with_full_queue(|| {
        // /v1: typed envelope + Retry-After.
        let mut c = Client::connect(st.handle.addr).unwrap();
        let r = c.post_json("/v1/predict", &predict_body(1, 77)).unwrap();
        assert_eq!(r.status, 429, "{}", String::from_utf8_lossy(&r.body));
        assert_eq!(error_code(&r.json_body().unwrap()), "server.overloaded");
        assert_eq!(r.header("retry-after"), Some("1"));

        // /v2 (OIP): one-string error leading with the same code, same
        // header, same queue (the `_ensemble` route shares TargetKey::Ensemble).
        let frame = vec![0.5f32; workload::IMG * workload::IMG];
        let body = flexserve::http::client::v2_infer_body(
            &[1, workload::IMG, workload::IMG, 1],
            &frame,
        );
        let r = c
            .post_json("/v2/models/_ensemble/infer", &body)
            .unwrap();
        assert_eq!(r.status, 429, "{}", String::from_utf8_lossy(&r.body));
        let msg = r.json_body().unwrap();
        assert!(
            msg.get("error").unwrap().as_str().unwrap().starts_with("server.overloaded:"),
            "{msg:?}"
        );
        assert_eq!(r.header("retry-after"), Some("1"));

        // Bogus subset names fail fast with their own taxonomy (404) —
        // they must NOT mint fresh per-subset queues that sidestep the
        // admission bound, nor wait out the batching window.
        let mut bogus = predict_body(1, 78);
        if let Value::Obj(m) = &mut bogus {
            m.push(("models".into(), Value::Arr(vec![Value::from("bogus")])));
        }
        let r = c.post_json("/v1/predict", &bogus).unwrap();
        assert_eq!(r.status, 404, "{}", String::from_utf8_lossy(&r.body));
        assert_eq!(error_code(&r.json_body().unwrap()), "model.unknown");

        // Duplicate names in a subset are a typed 422 before enqueue —
        // `[mlp,mlp]`, `[mlp,mlp,mlp]`, … are distinct spellings that
        // would each mint their own queue under the admission cap.
        let mut dup = predict_body(1, 79);
        if let Value::Obj(m) = &mut dup {
            m.push((
                "models".into(),
                Value::Arr(vec![Value::from("mlp"), Value::from("mlp")]),
            ));
        }
        let r = c.post_json("/v1/predict", &dup).unwrap();
        assert_eq!(r.status, 422, "{}", String::from_utf8_lossy(&r.body));
        assert_eq!(error_code(&r.json_body().unwrap()), "bad_input.bad_value");
    });

    // The sheds surface in both metrics expositions.
    assert!(st.state.metrics.counter("sched_shed_overload_total") >= 2);
    let mut c = Client::connect(st.handle.addr).unwrap();
    let prom = c.get("/v1/metrics?format=prometheus").unwrap();
    let text = String::from_utf8(prom.body.clone()).unwrap();
    assert!(text.contains("flexserve_sched_shed_overload_total"), "{text}");
    assert!(text.contains("# TYPE flexserve_sched_queue_depth gauge"), "{text}");
    assert!(text.contains("flexserve_sched_window_us"), "{text}");
    let legacy = c.get("/v1/metrics").unwrap();
    let text = String::from_utf8(legacy.body.clone()).unwrap();
    assert!(text.contains("flexserve_sched_shed_overload_total"), "{text}");
    assert!(text.contains("flexserve_sched_queue_depth"), "{text}");
}

#[test]
fn expired_in_queue_request_sheds_504() {
    let _guard = GUARD.lock().unwrap();
    let st = stack();
    let addr = st.handle.addr;
    let before = st.state.metrics.counter("sched_shed_deadline_total");

    // Occupant opens the 800 ms window; the probe's 1 ms budget expires
    // while it queues behind it.
    let occupant = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.post_json("/v1/predict", &predict_body(1, 31)).unwrap()
    });
    std::thread::sleep(Duration::from_millis(100));

    let mut c = Client::connect(addr).unwrap();
    let mut body = predict_body(1, 32);
    if let Value::Obj(m) = &mut body {
        m.push(("timeout_ms".into(), Value::from(1u64)));
    }
    let r = c.post_json("/v1/predict", &body).unwrap();
    assert_eq!(r.status, 504, "{}", String::from_utf8_lossy(&r.body));
    assert_eq!(error_code(&r.json_body().unwrap()), "server.deadline_exceeded");

    assert_eq!(occupant.join().unwrap().status, 200);
    assert!(st.state.metrics.counter("sched_shed_deadline_total") > before);
}

#[test]
fn legacy_alias_flattens_shed_status_but_keeps_code_and_hint() {
    let _guard = GUARD.lock().unwrap();
    let st = stack();
    with_full_queue(|| {
        // The unversioned /predict flattens every status to the seed's 422
        // but the taxonomy code and the Retry-After hint survive.
        let mut c = Client::connect(st.handle.addr).unwrap();
        let r = c.post_json("/predict", &predict_body(1, 99)).unwrap();
        assert_eq!(r.status, 422, "{}", String::from_utf8_lossy(&r.body));
        assert_eq!(error_code(&r.json_body().unwrap()), "server.overloaded");
        assert_eq!(r.header("retry-after"), Some("1"));
    });
}

#[test]
fn shutdown_drains_queued_requests() {
    let _guard = GUARD.lock().unwrap();
    // A scheduler of our own (over the same live ensemble) so dropping it
    // doesn't disturb the shared server.
    let ensemble = stack().state.ensemble.clone();
    let sched = Arc::new(
        Scheduler::spawn(
            ensemble,
            SchedConfig {
                max_batch: 32,
                max_delay: Duration::from_secs(5), // far longer than the test
                adaptive: false,
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        )
        .unwrap(),
    );
    let s2 = Arc::clone(&sched);
    let submitter = std::thread::spawn(move || {
        let mut rng = Prng::new(5);
        let (data, _) = workload::make_batch(&mut rng, 1);
        s2.submit(TargetKey::Ensemble, data, 1, None, None)
    });
    // Wait until the request is parked inside the 5 s window…
    for _ in 0..200 {
        if sched.queue_depth() > 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(sched.queue_depth() > 0, "request never enqueued");
    // …then begin shutdown. Drain semantics: the queued request must be
    // ANSWERED (flushed through the ensemble), not dropped — and long
    // before its 5 s window would have fired.
    let sw = std::time::Instant::now();
    sched.drain();
    let result = submitter.join().unwrap();
    let (output, stats) = result.expect("drained request succeeds");
    assert_eq!(output.batch, 1);
    assert_eq!(stats.coalesced_requests, 1);
    assert!(
        sw.elapsed() < Duration::from_secs(4),
        "drain waited out the window instead of flushing"
    );
    // Post-drain submissions are refused, not silently queued forever.
    let mut rng = Prng::new(6);
    let (data, _) = workload::make_batch(&mut rng, 1);
    assert!(sched.submit(TargetKey::Ensemble, data, 1, None, None).is_err());
}

#[test]
fn bounded_drain_sheds_queued_requests_typed() {
    let _guard = GUARD.lock().unwrap();
    let ensemble = stack().state.ensemble.clone();
    let metrics = Arc::new(Metrics::new());
    // drain_timeout ZERO: the deadline has provably passed by the time the
    // planner wakes from drain()'s notify, so the parked request MUST take
    // the shed path — no timing window in the assertion.
    let sched = Arc::new(
        Scheduler::spawn(
            ensemble,
            SchedConfig {
                max_batch: 32,
                max_delay: Duration::from_secs(5), // parks the request
                adaptive: false,
                drain_timeout: Some(Duration::ZERO),
                ..Default::default()
            },
            Arc::clone(&metrics),
        )
        .unwrap(),
    );
    let s2 = Arc::clone(&sched);
    let submitter = std::thread::spawn(move || {
        let mut rng = Prng::new(7);
        let (data, _) = workload::make_batch(&mut rng, 1);
        s2.submit(TargetKey::Ensemble, data, 1, None, None)
    });
    for _ in 0..200 {
        if sched.queue_depth() > 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(sched.queue_depth() > 0, "request never enqueued");
    sched.drain();
    let err = submitter
        .join()
        .unwrap()
        .expect_err("expired drain must fail the queued request");
    let api = err
        .downcast_ref::<ApiError>()
        .expect("shed is typed, not an anyhow string");
    assert_eq!(api.status, 503);
    assert_eq!(api.code, "server.shutting_down");
    assert_eq!(metrics.counter("sched_shed_shutdown_total"), 1);
}
