//! Device-free tests over the scheduling plane's pure logic: the adaptive
//! window, the admission rule, deadline shedding, and the dequeue-time
//! wait capture. No artifacts, no PJRT — everything here runs in CI.

use flexserve::coordinator::sched::policy::{adaptive_window_us, ewma_update, NO_ESTIMATE};
use flexserve::coordinator::sched::queue::{admit, plan_take, Reply, TargetQueue};
use flexserve::coordinator::sched::TargetKey;
use flexserve::runtime::TensorView;
use flexserve::util::prop::check;
use std::sync::mpsc;
use std::time::Duration;

fn view(n: usize) -> TensorView {
    TensorView::from(vec![0.0f32; n])
}

fn reply() -> (mpsc::Sender<Reply>, mpsc::Receiver<Reply>) {
    mpsc::channel()
}

#[test]
fn prop_admission_is_exact() {
    check("admit iff depth < cap (cap 0 unbounded)", 400, |g| {
        let depth = g.int(0, 100);
        let cap = g.int(0, 100);
        assert_eq!(admit(depth, cap), cap == 0 || depth < cap);
    });
}

#[test]
fn prop_adaptive_window_expected_company() {
    // Whenever the window is non-zero, the EXPECTED next arrival (one
    // EWMA gap away) lands inside it — a window that cannot attract
    // company is pure latency and must collapse to pass-through.
    check("non-zero window expects company", 400, |g| {
        let max_delay = g.int(1, 10_000) as u64;
        let gap = g.f64(0.0, 20_000.0);
        let w = adaptive_window_us(gap, max_delay);
        assert!(w <= max_delay);
        if w > 0 {
            assert!(w as f64 + 1.0 >= gap, "next arrival outside window: gap {gap} w {w}");
            assert!(w as f64 + gap >= max_delay as f64 - 1.0, "gap {gap} w {w}");
        } else {
            // Zero window only when the expected arrival would miss it
            // (gap ≥ max_delay/2, modulo truncation slack).
            assert!(2.0 * gap >= max_delay as f64 - 2.0, "gap {gap} max {max_delay}");
        }
    });
}

#[test]
fn prop_ewma_converges_toward_steady_rate() {
    check("ewma converges", 100, |g| {
        let steady = g.f64(10.0, 5_000.0);
        let mut e = NO_ESTIMATE;
        for _ in 0..60 {
            e = ewma_update(e, steady);
        }
        assert!((e - steady).abs() < 1e-6 * steady.max(1.0), "e {e} steady {steady}");
    });
}

#[test]
fn fresh_queue_is_pass_through_then_widens_under_load() {
    let mut q = TargetQueue::new();
    // No arrivals yet: the window must be zero (no startup latency tax).
    assert_eq!(q.window_us(2000, true), 0);
    assert_eq!(q.ewma_gap_us(), NO_ESTIMATE);
    // A burst of back-to-back arrivals produces a finite gap estimate and
    // therefore a non-zero window against any generous-enough max_delay
    // (thresholds stay loose — CI wall clocks hiccup).
    for _ in 0..50 {
        let (tx, _rx) = reply();
        q.push(view(4), 1, None, tx);
    }
    let ewma = q.ewma_gap_us();
    assert!(ewma.is_finite(), "burst must seed the estimate");
    assert!(
        q.window_us(10_000_000, true) > 0,
        "tight burst (ewma {ewma}µs) must earn a window under a 10s cap"
    );
    assert!(q.window_us(2000, true) <= 2000, "window bounded by max_delay");
    // The fixed-window spelling ignores the estimate entirely.
    assert_eq!(q.window_us(2000, false), 2000);
}

#[test]
fn wait_is_captured_at_dequeue_not_after_execution() {
    // The seed's bug: BatchStats::wait_micros was read AFTER
    // Ensemble::forward returned, so reported queue wait included device
    // execution. Pin the fix: the wait is frozen AT dequeue — it can
    // never exceed the wall clock measured right after `take`, no matter
    // how long the "device forward" takes afterwards.
    let enqueue_clock = flexserve::util::Stopwatch::start();
    let mut q = TargetQueue::new();
    let (tx, _rx) = reply();
    q.push(view(4), 1, None, tx);
    std::thread::sleep(Duration::from_millis(20));
    let flush = q.take(32);
    let upper = enqueue_clock.elapsed_micros(); // wall clock at dequeue
    assert_eq!(flush.items.len(), 1);
    let wait = flush.items[0].wait_us;
    assert!(wait >= 15_000, "queued ~20ms, saw {wait}µs");
    std::thread::sleep(Duration::from_millis(80)); // the "device forward"
    assert!(
        flush.items[0].wait_us == wait && wait <= upper,
        "wait {}µs inflated past the dequeue-time wall clock {upper}µs",
        flush.items[0].wait_us
    );
}

#[test]
fn take_respects_plan_take_prefix() {
    let mut q = TargetQueue::new();
    for batch in [16usize, 16, 16] {
        let (tx, _rx) = reply();
        q.push(view(batch * 4), batch, None, tx);
    }
    let flush = q.take(32);
    assert_eq!(flush.items.len(), 2);
    assert_eq!(flush.rows, 32);
    assert_eq!(q.len(), 1, "third request stays queued");
    assert_eq!(plan_take(&[16, 16, 16], 32), 2, "same rule, same answer");
}

#[test]
fn expired_requests_shed_and_fresh_ones_survive() {
    let mut q = TargetQueue::new();
    let (tx_dead, rx_dead) = reply();
    let (tx_live, _rx_live) = reply();
    q.push(view(4), 1, Some(Duration::from_millis(1)), tx_dead);
    q.push(view(4), 1, Some(Duration::from_secs(60)), tx_live);
    std::thread::sleep(Duration::from_millis(10));
    let shed = q.shed_expired();
    assert_eq!(shed.len(), 1, "only the 1 ms deadline expired");
    assert!(shed[0].waited_us >= 1_000);
    assert_eq!(q.len(), 1, "the 60 s deadline survives");
    assert_eq!(q.rows(), 1, "row accounting follows the shed");
    // No-deadline requests never expire.
    let mut q2 = TargetQueue::new();
    let (tx, _rx) = reply();
    q2.push(view(4), 1, None, tx);
    std::thread::sleep(Duration::from_millis(5));
    assert!(q2.shed_expired().is_empty());
    drop(rx_dead);
}

#[test]
fn next_deadline_tracks_soonest_pending() {
    let mut q = TargetQueue::new();
    let (tx1, _r1) = reply();
    let (tx2, _r2) = reply();
    let (tx3, _r3) = reply();
    q.push(view(4), 1, None, tx1);
    assert!(q.next_deadline_us().is_none(), "no deadlines pending");
    q.push(view(4), 1, Some(Duration::from_secs(60)), tx2);
    q.push(view(4), 1, Some(Duration::from_millis(50)), tx3);
    let d = q.next_deadline_us().expect("deadlines pending");
    assert!(d <= 50_000, "soonest wins: {d}µs");
    assert!(d > 0, "fresh 50ms deadline is not yet expired");
}

#[test]
fn target_keys_separate_coalescing_domains() {
    // Same-shape requests with different targets must never share a key
    // (and therefore never a batch); same targets must.
    let ens = TargetKey::Ensemble;
    let single_a = TargetKey::Single("a".into());
    let single_b = TargetKey::Single("b".into());
    let sub_ab = TargetKey::Subset(vec!["a".into(), "b".into()]);
    let sub_ba = TargetKey::Subset(vec!["b".into(), "a".into()]);
    assert_eq!(ens, TargetKey::Ensemble);
    assert_eq!(single_a, TargetKey::Single("a".into()));
    assert_ne!(single_a, single_b);
    assert_ne!(TargetKey::Subset(vec!["a".into()]), single_a);
    // Order is part of the wire contract (response renders in request
    // order), so differently-ordered subsets keep separate queues.
    assert_ne!(sub_ab, sub_ba);
}

#[test]
fn prop_queue_rows_track_pushes() {
    check("queue rows == sum of pushed batches", 100, |g| {
        let n = g.int(1, 12);
        let sizes = g.vec_usize(n, 1, 9);
        let mut q = TargetQueue::new();
        let mut receivers = Vec::new();
        for &b in &sizes {
            let (tx, rx) = reply();
            receivers.push(rx);
            q.push(view(b), b, None, tx);
        }
        assert_eq!(q.rows(), sizes.iter().sum::<usize>());
        assert_eq!(q.len(), sizes.len());
        let cap = g.int(1, 40);
        let flush = q.take(cap);
        assert_eq!(flush.rows, sizes[..flush.items.len()].iter().sum::<usize>());
        assert_eq!(q.len(), sizes.len() - flush.items.len());
    });
}
