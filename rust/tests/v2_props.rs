//! Device-free differential tests for the `/v2` Open-Inference-Protocol
//! codec: a valid v2 infer body and the equivalent `/v1` predict body must
//! lower to the SAME protocol-agnostic IR tensor (so the core serves
//! identical predictions for identical inputs), and every malformed
//! dtype/shape/data-length case must reject with a stable, typed error.
//! The full-stack counterpart (real device, real outputs) lives in
//! `v2_integration.rs`.

use flexserve::coordinator::v2::{self, parse_infer};
use flexserve::coordinator::wire::PredictRequest;
use flexserve::http::Request;
use flexserve::json::{self, ser};
use flexserve::runtime::{DType, Manifest};
use flexserve::util::prop::check;
use std::path::PathBuf;

/// The same tiny manifest the wire-layer tests use (2x2x1 input, 4 floats
/// per sample) so shape validation runs without artifacts.
fn manifest() -> Manifest {
    let v = json::parse(
        r#"{
          "format_version": 1,
          "input_shape": [2, 2, 1],
          "classes": ["blank", "cross"],
          "normalize": {"mean": 0.0, "std": 1.0},
          "buckets": [1, 4],
          "models": {
            "m1": {
              "param_count": 1, "test_acc": 0.9, "params_sha256": "ab",
              "buckets": {"1": {"file": "f", "sha256": "x", "bytes": 1}}
            }
          }
        }"#,
    )
    .unwrap();
    Manifest::from_value(PathBuf::from("/tmp"), &v).unwrap()
}

fn v1_request(body: String) -> Request {
    Request::new("POST", "/v1/predict", body.into_bytes())
}

fn v2_request(body: String) -> Request {
    Request::new("POST", "/v2/models/_ensemble/infer", body.into_bytes())
}

/// Render the v2 body for one tensor, optionally with nested data.
fn v2_body(datatype: &str, shape: &[usize], data: &[f32], nested: bool) -> String {
    let mut out = String::from(r#"{"inputs":[{"name":"input","datatype":""#);
    out.push_str(datatype);
    out.push_str(r#"","shape":"#);
    let dims: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
    out.push_str(&format!("[{}]", dims.join(",")));
    out.push_str(r#","data":"#);
    if nested {
        // One nested array per row: [[row0...],[row1...]].
        let elems = data.len() / shape[0];
        out.push('[');
        for (i, row) in data.chunks(elems).enumerate() {
            if i > 0 {
                out.push(',');
            }
            ser::write_f32_array(&mut out, row.iter().copied());
        }
        out.push(']');
    } else {
        ser::write_f32_array(&mut out, data.iter().copied());
    }
    out.push_str("}]}");
    out
}

#[test]
fn prop_v2_and_v1_bodies_lower_to_the_same_tensor() {
    let m = manifest();
    let elems = m.sample_elems();
    check("v2 infer body ≡ v1 predict body in the IR", 300, |g| {
        let batch = g.int(1, 5);
        // Integral values so FP32/INT64/UINT8 spellings describe the same
        // tensor (UINT8 additionally needs the 0..=255 range).
        let data: Vec<f32> = (0..batch * elems).map(|_| g.int(0, 255) as f32).collect();
        let dtype = *g.choose(&["FP32", "INT64", "UINT8"]);
        let nested = g.bool(0.4);
        // v2 accepts the full shape or the flattened [N, elems] spelling.
        let full_shape = [batch, 2, 2, 1];
        let flat_shape = [batch, elems];
        let shape: &[usize] = if g.bool(0.5) { &full_shape } else { &flat_shape };

        let (ir, _) = parse_infer(&m, &v2_request(v2_body(dtype, shape, &data, nested)), true)
            .unwrap_or_else(|e| panic!("valid v2 body rejected ({e}): dtype={dtype}"));

        let mut v1 = String::from(r#"{"data":"#);
        ser::write_f32_array(&mut v1, data.iter().copied());
        v1.push_str(&format!(r#","batch":{batch}}}"#));
        let parsed = PredictRequest::parse(&m, &v1_request(v1)).unwrap();
        let v1_ir = parsed.into_inference(&m);

        assert_eq!(ir.batch, v1_ir.batch);
        assert_eq!(ir.inputs[0].data, v1_ir.inputs[0].data, "dtype={dtype}");
        assert_eq!(ir.inputs[0].dtype, DType::from_v2(dtype).unwrap());
        // Both spell a [batch, ...] shape whose product covers the data.
        assert_eq!(ir.inputs[0].shape[0], batch);
        assert_eq!(
            ir.inputs[0].shape.iter().product::<usize>(),
            batch * elems
        );
    });
}

#[test]
fn prop_malformed_v2_bodies_reject_with_typed_errors() {
    let m = manifest();
    check("malformed v2 bodies reject, never panic", 300, |g| {
        let batch = g.int(1, 4);
        let elems = m.sample_elems();
        let good: Vec<f32> = (0..batch * elems).map(|_| g.int(0, 9) as f32).collect();
        let (body, want_code) = match g.int(0, 5) {
            // Wrong per-sample dims.
            0 => (
                v2_body("FP32", &[batch, 3, 3], &good, false),
                "bad_input.shape_mismatch",
            ),
            // Data length disagrees with the shape.
            1 => (
                v2_body("FP32", &[batch + 1, elems], &good, false),
                "bad_input.shape_mismatch",
            ),
            // Unsupported datatype.
            2 => (
                v2_body("FP64", &[batch, elems], &good, false),
                "bad_input.dtype",
            ),
            // BYTES is rejected for numeric models.
            3 => (
                v2_body("BYTES", &[batch, elems], &good, false),
                "bad_input.dtype",
            ),
            // Zero batch dimension.
            4 => (v2_body("FP32", &[0, elems], &[], false), "bad_input.bad_value"),
            // Non-integer data under an integer dtype.
            _ => {
                let mut data = good.clone();
                data[0] = 0.5;
                (
                    v2_body("INT64", &[batch, elems], &data, false),
                    "bad_input.bad_value",
                )
            }
        };
        let err = parse_infer(&m, &v2_request(body.clone()), true)
            .err()
            .unwrap_or_else(|| panic!("malformed body accepted: {body}"));
        assert_eq!(err.code, want_code, "{body}");
        assert_eq!(err.status, 422, "{body}");
        // The rendered protocol error is the stable `code: message` string.
        let resp = v2::v2_error(&err);
        let rendered = resp.json_body().unwrap();
        let s = rendered.get("error").unwrap().as_str().unwrap().to_string();
        assert!(s.starts_with(&format!("{}: ", want_code)), "{s}");
    });
}

#[test]
fn v2_error_strings_are_stable_across_equivalent_requests() {
    // The same malformed request must produce byte-identical error strings
    // on repeat — clients can match on them.
    let m = manifest();
    let body = v2_body("FP64", &[1, 4], &[1.0, 2.0, 3.0, 4.0], false);
    let first = parse_infer(&m, &v2_request(body.clone()), true).unwrap_err();
    for _ in 0..3 {
        let again = parse_infer(&m, &v2_request(body.clone()), true).unwrap_err();
        assert_eq!(
            (again.status, again.code, again.message.clone()),
            (first.status, first.code, first.message.clone())
        );
    }
}

#[test]
fn v2_client_body_builder_parses_back() {
    // The typed client's body builder emits exactly what the codec accepts.
    let m = manifest();
    let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
    let body = flexserve::http::client::v2_infer_body(&[2, 2, 2, 1], &data);
    let req = v2_request(json::to_string(&body));
    let (ir, _) = parse_infer(&m, &req, true).unwrap();
    assert_eq!(ir.batch, 2);
    assert_eq!(ir.inputs[0].data, data);
    assert_eq!(ir.inputs[0].dtype, DType::F32);
}

#[test]
fn v2_output_filter_and_params_survive_lowering() {
    let m = manifest();
    let body = r#"{"id":"abc",
        "inputs":[{"name":"input","datatype":"FP32","shape":[1,4],"data":[1,2,3,4]}],
        "parameters":{"detail":true,"normalized":true},
        "outputs":[{"name":"m1.classes"},{"name":"m1.probs"}]}"#;
    let (ir, opts) = parse_infer(&m, &v2_request(body.to_string()), true).unwrap();
    assert!(ir.params.detail && ir.params.normalized);
    assert_eq!(opts.id.as_deref(), Some("abc"));
    assert_eq!(
        opts.outputs,
        Some(vec!["m1.classes".to_string(), "m1.probs".to_string()])
    );
}
