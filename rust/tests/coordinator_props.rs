//! Property tests over coordinator invariants (no device needed): JSON
//! round-trips, policy algebra, batcher coalescing/slicing, padding.

use flexserve::coordinator::policy::Policy;
use flexserve::json::{self, Value};
use flexserve::runtime::tensor::{argmax_rows, pad_batch, softmax_rows, truncate_batch};
use flexserve::util::prop::{check, Gen};

fn gen_value(g: &mut Gen, depth: usize) -> Value {
    match if depth >= 3 { g.int(0, 3) } else { g.int(0, 5) } {
        0 => Value::Null,
        1 => Value::Bool(g.bool(0.5)),
        2 => {
            // Integers and "nice" floats survive f64 round-trips exactly.
            if g.bool(0.5) {
                Value::Num(g.int(0, 1_000_000) as f64 - 500_000.0)
            } else {
                Value::Num((g.int(0, 1000) as f64) / 64.0)
            }
        }
        3 => Value::Str(g.string(12)),
        4 => Value::Arr((0..g.int(0, 4)).map(|_| gen_value(g, depth + 1)).collect()),
        _ => Value::Obj(
            (0..g.int(0, 4))
                .map(|i| (format!("k{i}_{}", g.string(4).len()), gen_value(g, depth + 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip_compact_and_pretty() {
    check("json roundtrip", 500, |g| {
        let v = gen_value(g, 0);
        let compact = json::to_string(&v);
        assert_eq!(json::parse(&compact).unwrap(), v, "compact {compact}");
        let pretty = json::to_string_pretty(&v);
        assert_eq!(json::parse(&pretty).unwrap(), v, "pretty {pretty}");
    });
}

#[test]
fn prop_pad_truncate_identity() {
    check("pad/truncate identity", 300, |g| {
        let batch = g.int(1, 16);
        let elems = g.int(1, 64);
        let bucket = batch + g.int(0, 16);
        let data = g.vec_f32(batch * elems, -10.0, 10.0);
        let padded = pad_batch(&data, batch, bucket, elems);
        assert_eq!(padded.len(), bucket * elems);
        // Padding rows are zero.
        assert!(padded[batch * elems..].iter().all(|&v| v == 0.0));
        let back = truncate_batch(padded, batch, elems);
        assert_eq!(back, data);
    });
}

#[test]
fn prop_softmax_normalizes_and_preserves_argmax() {
    check("softmax invariants", 300, |g| {
        let rows = g.int(1, 8);
        let classes = g.int(2, 10);
        let logits = g.vec_f32(rows * classes, -50.0, 50.0);
        let arg_before = argmax_rows(&logits, classes);
        let mut probs = logits.clone();
        softmax_rows(&mut probs, classes);
        let arg_after = argmax_rows(&probs, classes);
        for row in 0..rows {
            assert_eq!(arg_before[row].0, arg_after[row].0, "argmax changed");
        }
        for row in probs.chunks(classes) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "sum {sum}");
            assert!(row.iter().all(|p| (0.0..=1.0001).contains(p)));
        }
    });
}

#[test]
fn prop_weighted_policy_generalizes_atleast() {
    // Weighted with unit weights and threshold k ≡ AtLeast(k).
    check("weighted == atleast under unit weights", 300, |g| {
        let n = g.int(1, 8);
        let k = g.int(1, n);
        let votes: Vec<bool> = (0..n).map(|_| g.bool(0.4)).collect();
        let weighted = Policy::Weighted {
            weights: vec![1.0; n],
            threshold: k as f64,
        };
        assert_eq!(
            weighted.fuse(&votes).unwrap(),
            Policy::AtLeast(k).fuse(&votes).unwrap(),
            "votes {votes:?} k {k}"
        );
    });
}

#[test]
fn prop_policy_complement_duality() {
    // All(votes) == !Any(!votes) — De Morgan over the vote vector.
    check("policy De Morgan duality", 300, |g| {
        let n = g.int(1, 9);
        let votes: Vec<bool> = (0..n).map(|_| g.bool(0.5)).collect();
        let inverted: Vec<bool> = votes.iter().map(|v| !v).collect();
        assert_eq!(
            Policy::All.fuse(&votes).unwrap(),
            !Policy::Any.fuse(&inverted).unwrap()
        );
    });
}

#[test]
fn prop_http_request_query_parse_total() {
    // The query parser must never panic on arbitrary ASCII junk.
    check("query parser total", 300, |g| {
        let len = g.int(0, 30);
        let junk: String = (0..len)
            .map(|_| *g.choose(&['a', '=', '&', '?', '/', '1', '%']))
            .collect();
        let req = flexserve::http::Request::new("GET", &format!("/p?{junk}"), Vec::new());
        let _ = req.query_param("a");
        assert_eq!(req.path, "/p");
    });
}
