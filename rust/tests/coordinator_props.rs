//! Property tests over coordinator invariants (no device needed): JSON
//! round-trips, policy algebra, batcher coalescing/slicing, padding, and
//! the differential contract between the streaming `"data"` scanner and
//! the general recursive-descent parser.

use flexserve::coordinator::policy::Policy;
use flexserve::coordinator::wire::{scan_predict_body, PredictRequest};
use flexserve::http::Request;
use flexserve::json::{self, Value};
use flexserve::runtime::tensor::{argmax_rows, pad_batch, softmax_rows, truncate_batch};
use flexserve::runtime::Manifest;
use flexserve::util::prop::{check, Gen};
use std::path::PathBuf;

fn gen_value(g: &mut Gen, depth: usize) -> Value {
    match if depth >= 3 { g.int(0, 3) } else { g.int(0, 5) } {
        0 => Value::Null,
        1 => Value::Bool(g.bool(0.5)),
        2 => {
            // Integers and "nice" floats survive f64 round-trips exactly.
            if g.bool(0.5) {
                Value::Num(g.int(0, 1_000_000) as f64 - 500_000.0)
            } else {
                Value::Num((g.int(0, 1000) as f64) / 64.0)
            }
        }
        3 => Value::Str(g.string(12)),
        4 => Value::Arr((0..g.int(0, 4)).map(|_| gen_value(g, depth + 1)).collect()),
        _ => Value::Obj(
            (0..g.int(0, 4))
                .map(|i| (format!("k{i}_{}", g.string(4).len()), gen_value(g, depth + 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip_compact_and_pretty() {
    check("json roundtrip", 500, |g| {
        let v = gen_value(g, 0);
        let compact = json::to_string(&v);
        assert_eq!(json::parse(&compact).unwrap(), v, "compact {compact}");
        let pretty = json::to_string_pretty(&v);
        assert_eq!(json::parse(&pretty).unwrap(), v, "pretty {pretty}");
    });
}

#[test]
fn prop_pad_truncate_identity() {
    check("pad/truncate identity", 300, |g| {
        let batch = g.int(1, 16);
        let elems = g.int(1, 64);
        let bucket = batch + g.int(0, 16);
        let data = g.vec_f32(batch * elems, -10.0, 10.0);
        let padded = pad_batch(&data, batch, bucket, elems);
        assert_eq!(padded.len(), bucket * elems);
        // Padding rows are zero.
        assert!(padded[batch * elems..].iter().all(|&v| v == 0.0));
        let back = truncate_batch(padded, batch, elems);
        assert_eq!(back, data);
    });
}

#[test]
fn prop_softmax_normalizes_and_preserves_argmax() {
    check("softmax invariants", 300, |g| {
        let rows = g.int(1, 8);
        let classes = g.int(2, 10);
        let logits = g.vec_f32(rows * classes, -50.0, 50.0);
        let arg_before = argmax_rows(&logits, classes);
        let mut probs = logits.clone();
        softmax_rows(&mut probs, classes);
        let arg_after = argmax_rows(&probs, classes);
        for row in 0..rows {
            assert_eq!(arg_before[row].0, arg_after[row].0, "argmax changed");
        }
        for row in probs.chunks(classes) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "sum {sum}");
            assert!(row.iter().all(|p| (0.0..=1.0001).contains(p)));
        }
    });
}

#[test]
fn prop_weighted_policy_generalizes_atleast() {
    // Weighted with unit weights and threshold k ≡ AtLeast(k).
    check("weighted == atleast under unit weights", 300, |g| {
        let n = g.int(1, 8);
        let k = g.int(1, n);
        let votes: Vec<bool> = (0..n).map(|_| g.bool(0.4)).collect();
        let weighted = Policy::Weighted {
            weights: vec![1.0; n],
            threshold: k as f64,
        };
        assert_eq!(
            weighted.fuse(&votes).unwrap(),
            Policy::AtLeast(k).fuse(&votes).unwrap(),
            "votes {votes:?} k {k}"
        );
    });
}

#[test]
fn prop_policy_complement_duality() {
    // All(votes) == !Any(!votes) — De Morgan over the vote vector.
    check("policy De Morgan duality", 300, |g| {
        let n = g.int(1, 9);
        let votes: Vec<bool> = (0..n).map(|_| g.bool(0.5)).collect();
        let inverted: Vec<bool> = votes.iter().map(|v| !v).collect();
        assert_eq!(
            Policy::All.fuse(&votes).unwrap(),
            !Policy::Any.fuse(&inverted).unwrap()
        );
    });
}

// ---- fast scanner ≡ general parser -------------------------------------

/// A tiny manifest (2x2x1 input, 4 floats per sample) so shape validation
/// in `PredictRequest` is exercised without artifacts.
fn prop_manifest() -> Manifest {
    let v = json::parse(
        r#"{
          "format_version": 1,
          "input_shape": [2, 2, 1],
          "classes": ["blank", "cross"],
          "normalize": {"mean": 0.0, "std": 1.0},
          "buckets": [1, 4],
          "models": {
            "m1": {
              "param_count": 1, "test_acc": 0.9, "params_sha256": "ab",
              "buckets": {"1": {"file": "f", "sha256": "x", "bytes": 1}}
            }
          }
        }"#,
    )
    .unwrap();
    Manifest::from_value(PathBuf::from("/tmp"), &v).unwrap()
}

/// One array element: mostly well-formed floats in every spelling the
/// grammar allows (ints, decimals, exponents), plus the classics the
/// scanner must NOT accept differently (NaN/Inf words, leading zeros,
/// bare dots, strings, nested junk).
fn gen_float_token(g: &mut Gen) -> String {
    match g.int(0, 11) {
        0 => format!("{}", g.int(0, 1000)),
        1 => format!("-{}", g.int(0, 1000)),
        2 => format!("{}.{}", g.int(0, 50), g.int(0, 999)),
        3 => format!("-{}.{}", g.int(0, 9), g.int(0, 99)),
        4 => format!("{}e{}", g.int(1, 9), g.int(0, 3)),
        5 => format!("{}.{}E-{}", g.int(0, 9), g.int(0, 9), g.int(0, 2)),
        6 => format!("{}e+{}", g.int(1, 9), g.int(0, 2)),
        7 => "1e999".to_string(), // f64 inf → rejected as non-finite f32
        8 => "0".to_string(),
        9 => format!("{}", (g.int(0, 2_000_000) as f64 - 1_000_000.0) / 977.0),
        _ => (*g.choose(&[
            "NaN", "Infinity", "-Inf", "01", "1.", ".5", "+1", "-", "0x1", "1e", "1e+",
            "true", "null", "\"x\"", "[1]", "{}",
        ]))
        .to_string(),
    }
}

/// Random whitespace (valid JSON separators only).
fn gen_ws(g: &mut Gen) -> &'static str {
    *g.choose(&["", "", "", " ", "  ", "\n", "\t ", " \r\n "])
}

/// A predict body: usually `{"data": [...]}` plus optional small members,
/// then possibly mutated (truncation / trailing garbage / mid-body junk)
/// so malformed inputs are covered too.
fn gen_predict_body(g: &mut Gen) -> String {
    let mut body = String::from("{");
    let n = g.int(0, 10);
    body.push_str(gen_ws(g));
    body.push_str("\"data\"");
    body.push_str(gen_ws(g));
    body.push(':');
    body.push_str(gen_ws(g));
    body.push('[');
    for i in 0..n {
        if i > 0 {
            body.push(',');
        }
        body.push_str(gen_ws(g));
        body.push_str(&gen_float_token(g));
        body.push_str(gen_ws(g));
    }
    body.push(']');
    if g.bool(0.5) {
        body.push_str(&format!(",{}\"batch\"{}:{}", gen_ws(g), gen_ws(g), g.int(0, 5)));
    }
    if g.bool(0.3) {
        body.push_str(",\"normalized\":true");
    }
    if g.bool(0.25) {
        body.push_str(",\"detail\":1"); // wrong type on purpose: both paths must agree
    }
    if g.bool(0.2) {
        body.push_str(",\"models\":[\"m1\"]");
    }
    if g.bool(0.15) {
        body.push_str(",\"junk\":{\"nested\":[1,{\"k\":null}]}");
    }
    if g.bool(0.2) {
        // Sometimes valid, sometimes the typed zero/junk rejections —
        // both paths must agree either way.
        body.push_str(&format!(
            ",\"timeout_ms\":{}",
            g.choose(&["250", "1", "0", "-5", "\"fast\"", "2.5"])
        ));
    }
    if g.bool(0.1) {
        body.push_str(",\"data\":[1,2]"); // duplicate member
    }
    if g.bool(0.1) {
        body.push_str(",\"pgm_b64\":[\"aGk=\"]");
    }
    body.push('}');
    // Structural mutations (bodies are pure ASCII, so any byte index is a
    // char boundary).
    match g.int(0, 11) {
        0 => {
            let cut = g.int(0, body.len());
            body.truncate(cut);
        }
        1 => body.push_str(" junk"),
        2 => {
            let at = g.int(0, body.len());
            body.insert(at, *g.choose(&['!', '}', ',', 'x']));
        }
        _ => {}
    }
    body
}

#[test]
fn prop_fast_parse_matches_general_parse() {
    let manifest = prop_manifest();
    check("fast predict parse ≡ general parse", 800, |g| {
        let body = gen_predict_body(g);
        let req = Request::new("POST", "/v1/predict", body.clone().into_bytes());
        let fast = PredictRequest::parse(&manifest, &req);
        let slow = PredictRequest::parse_general(&manifest, &req);
        match (fast, slow) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.data, b.data, "data mismatch for {body:?}");
                assert_eq!(a.batch, b.batch, "batch mismatch for {body:?}");
                assert_eq!(a.normalized, b.normalized, "{body:?}");
                assert_eq!(a.models, b.models, "{body:?}");
                assert_eq!(a.detail, b.detail, "{body:?}");
                assert_eq!(a.timeout, b.timeout, "{body:?}");
            }
            (Err(a), Err(b)) => assert_eq!(
                (a.status, a.code),
                (b.status, b.code),
                "error mismatch for {body:?}: '{a}' vs '{b}'"
            ),
            (a, b) => panic!(
                "accept/reject divergence for {body:?}: fast_ok={} general_ok={}",
                a.is_ok(),
                b.is_ok()
            ),
        }
    });
}

#[test]
fn prop_scanner_agrees_with_value_tree() {
    check("scanner floats ≡ Value-tree floats", 600, |g| {
        let body = gen_predict_body(g);
        let Some((data, rest)) = scan_predict_body(&body) else {
            return; // fallback case — covered by the differential test
        };
        // Anything the scanner accepts, the general parser must accept…
        let v = json::parse(&body)
            .unwrap_or_else(|e| panic!("scanner accepted, parser rejected {body:?}: {e}"));
        // …with bit-identical floats…
        let tree = v
            .get("data")
            .and_then(Value::as_f32_vec)
            .unwrap_or_else(|| panic!("scanner accepted non-numeric data in {body:?}"));
        assert_eq!(data, tree, "{body:?}");
        // …and identical non-data members.
        for key in ["batch", "normalized", "detail", "models", "junk", "pgm_b64"] {
            assert_eq!(rest.get(key), v.get(key), "member '{key}' of {body:?}");
        }
    });
}

#[test]
fn prop_http_request_query_parse_total() {
    // The query parser must never panic on arbitrary ASCII junk.
    check("query parser total", 300, |g| {
        let len = g.int(0, 30);
        let junk: String = (0..len)
            .map(|_| *g.choose(&['a', '=', '&', '?', '/', '1', '%']))
            .collect();
        let req = flexserve::http::Request::new("GET", &format!("/p?{junk}"), Vec::new());
        let _ = req.query_param("a");
        assert_eq!(req.path, "/p");
    });
}
