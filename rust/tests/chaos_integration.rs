//! Device-free failure-containment integration tests.
//!
//! The chaos plane is a process-global singleton (`chaos::install` is
//! once-only), so every assertion that depends on the installed plane
//! lives in ONE test (`chaos_containment_end_to_end`); the remaining
//! tests use instance-level `ChaosPlane`s or no chaos at all.

use flexserve::chaos::{self, ChaosPlane, FaultKind};
use flexserve::coordinator::{ApiError, BreakerConfig, Breakers, Metrics};
use flexserve::http::{Client, Request, Response, Router, Server};
use flexserve::json::{self, Value};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn post_predict(c: &mut Client) -> anyhow::Result<Response> {
    c.request(&Request::new("POST", "/v1/predict", b"{}".to_vec()))
}

fn error_code(resp: &Response) -> Option<String> {
    resp.json_body()
        .ok()
        .and_then(|b| b.path(&["error", "code"]).and_then(Value::as_str).map(str::to_string))
}

/// Same spec + same seed = same injection sequence; disarming stops
/// injection without losing the counters.
#[test]
fn chaos_plane_is_seeded_and_deterministic() {
    let spec = "exec.device=0.5:panic,sched.flush=0.25:error";
    let a = ChaosPlane::parse(spec, 123).unwrap();
    let b = ChaosPlane::parse(spec, 123).unwrap();
    let seq_a: Vec<Option<FaultKind>> = (0..64).map(|_| a.decide(chaos::EXEC_DEVICE)).collect();
    let seq_b: Vec<Option<FaultKind>> = (0..64).map(|_| b.decide(chaos::EXEC_DEVICE)).collect();
    assert_eq!(seq_a, seq_b, "same seed must replay the same faults");
    assert!(seq_a.iter().any(Option::is_some), "50% rate injects within 64 draws");
    assert!(seq_a.iter().any(Option::is_none), "50% rate passes within 64 draws");
    assert_eq!(
        a.injected(chaos::EXEC_DEVICE),
        seq_a.iter().filter(|d| d.is_some()).count() as u64
    );
    // A different seed diverges somewhere in a window this long.
    let c = ChaosPlane::parse(spec, 124).unwrap();
    let seq_c: Vec<Option<FaultKind>> = (0..64).map(|_| c.decide(chaos::EXEC_DEVICE)).collect();
    assert_ne!(seq_a, seq_c, "different seed, different schedule");

    // Unconfigured sites never fire; disarming silences configured ones.
    assert_eq!(a.decide(chaos::GATEWAY_CONNECT), None);
    a.set_armed(false);
    let before = a.injected(chaos::EXEC_DEVICE);
    assert!((0..64).all(|_| a.decide(chaos::EXEC_DEVICE).is_none()));
    assert_eq!(a.injected(chaos::EXEC_DEVICE), before, "disarmed draws don't count");
}

#[test]
fn chaos_spec_grammar_rejects_nonsense() {
    assert!(ChaosPlane::parse("exec.device=0.5:panic", 0).is_ok());
    assert!(ChaosPlane::parse("bogus.site=0.5:panic", 0).is_err(), "unknown site");
    assert!(ChaosPlane::parse("exec.device=0:panic", 0).is_err(), "rate 0 is not a rule");
    assert!(ChaosPlane::parse("exec.device=1.5:panic", 0).is_err(), "rate > 1");
    assert!(ChaosPlane::parse("exec.device=0.5:frobnicate", 0).is_err(), "unknown kind");
    assert!(
        ChaosPlane::parse("exec.device=0.5:panic,exec.device=0.2:drop", 0).is_err(),
        "duplicate site"
    );
    assert!(ChaosPlane::parse("exec.device", 0).is_err(), "missing rate:kind");
}

/// A panicking handler over a LIVE server (real socket, real worker
/// thread) answers a typed 500 and the connection worker survives to
/// serve the next request — the router's panic guard, end to end.
#[test]
fn panicking_handler_answers_typed_500_over_live_server() {
    let mut router = Router::new();
    router.add("GET", "/boom", |_req, _p| panic!("kaboom"));
    router.add("GET", "/ok", |_req, _p| {
        Response::json(200, &json::obj([("ok", Value::from(true))]))
    });
    let server = Server::spawn("127.0.0.1:0", 1, router.into_handler()).unwrap();
    let mut c = Client::connect(server.addr).unwrap();
    c.set_timeout(Duration::from_secs(5)).unwrap();

    let resp = c.get("/boom").unwrap();
    assert_eq!(resp.status, 500);
    assert_eq!(error_code(&resp).as_deref(), Some("internal"));

    // One worker thread: if the panic poisoned it, this request hangs or
    // dies instead of answering.
    let resp = c.get("/ok").unwrap();
    assert_eq!(resp.status, 200);
    let resp = c.get("/boom").unwrap();
    assert_eq!(resp.status, 500, "guard holds on repeat panics");
    server.stop();
}

/// The acceptance scenario: a seeded spec injecting executor panics and
/// gateway connection drops, driven over live HTTP. Every request gets a
/// 2xx or a *typed* error (never an untyped 500, never a hang), the
/// breaker opens and — once the plane is disarmed — recovers through
/// half-open, all observable in the shared metrics registry.
#[test]
fn chaos_containment_end_to_end() {
    let metrics = Arc::new(Metrics::new());
    let plane = ChaosPlane::parse("exec.device=0.4:panic,gateway.connect=0.3:drop", 11).unwrap();
    chaos::install(plane).unwrap();
    chaos::set_sink(Arc::clone(&metrics));

    // The backend: real breakers gating a simulated device forward whose
    // failure source is the exec.device injection site.
    let breakers = Arc::new(Breakers::new(
        BreakerConfig {
            fail_threshold: 2,
            cooldown: Duration::from_millis(250),
        },
        Arc::clone(&metrics),
    ));
    let key = Breakers::key("echo", 1);
    let mut router = Router::new();
    {
        let breakers = Arc::clone(&breakers);
        let key = key.clone();
        router.add("POST", "/v1/predict", move |_req, _p| {
            if let Err(e) = breakers.check(&key) {
                return e.to_response();
            }
            match chaos::decide(chaos::EXEC_DEVICE) {
                Some(kind) => {
                    breakers.record(&key, false);
                    ApiError::worker_crashed(format!("chaos: injected {}", kind.as_str()))
                        .to_response()
                }
                None => {
                    breakers.record(&key, true);
                    Response::json(200, &json::obj([("ok", Value::from(true))]))
                }
            }
        });
    }
    router.add("GET", "/v1/healthz", |_req, _p| {
        Response::json(
            200,
            &json::obj([
                ("status", Value::from("ok")),
                ("ready", Value::from(true)),
                ("active", Value::Arr(vec![Value::from("echo")])),
            ]),
        )
    });
    let backend = Server::spawn("127.0.0.1:0", 4, router.into_handler()).unwrap();
    let mut c = Client::connect(backend.addr).unwrap();
    // The read timeout is the hang detector: a request that never answers
    // fails the test here instead of wedging it.
    c.set_timeout(Duration::from_secs(5)).unwrap();

    let (mut ok, mut crashed, mut open) = (0u32, 0u32, 0u32);
    for i in 0..250 {
        let resp = post_predict(&mut c).unwrap_or_else(|e| panic!("request {i} hung/died: {e}"));
        if resp.status == 200 {
            ok += 1;
            continue;
        }
        let code = error_code(&resp)
            .unwrap_or_else(|| panic!("request {i}: untyped {} response", resp.status));
        match code.as_str() {
            "exec.worker_crashed" => crashed += 1,
            "exec.circuit_open" => {
                assert!(
                    resp.header("retry-after").is_some(),
                    "circuit_open without Retry-After"
                );
                open += 1;
            }
            other => panic!("request {i}: unexpected error code '{other}'"),
        }
    }
    assert!(ok > 0, "some requests must succeed");
    assert!(crashed > 0, "40% panic rate must surface typed worker_crashed errors");
    assert!(
        metrics.counter("breaker_open_total") >= 1,
        "threshold-2 breaker must open under a 40% failure rate (opens seen: {open})"
    );
    assert!(metrics.counter("chaos_inject_exec_device_total") > 0);

    // The same backend behind the real gateway: connection drops at the
    // gateway.connect site degrade to typed errors, not hangs.
    let mut gcfg = flexserve::config::GatewayConfig::default();
    gcfg.addr = "127.0.0.1:0".into();
    gcfg.backends = vec![("b0".to_string(), backend.addr.to_string())];
    gcfg.probe_interval = Duration::from_millis(50);
    gcfg.probe_connect_timeout = Duration::from_millis(100);
    gcfg.probe_timeout = Duration::from_millis(250);
    gcfg.probe_jitter = Duration::from_millis(10);
    gcfg.rise_after = 1;
    gcfg.retry_budget = 0; // single sleep-free attempt per request
    let gw = flexserve::gateway::spawn(gcfg).unwrap();
    let mut gc = Client::connect(gw.server.addr).unwrap();
    gc.set_timeout(Duration::from_secs(5)).unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let doc = gc.get("/v1/gateway").unwrap().json_body().unwrap();
        let state = doc
            .get("backends")
            .and_then(Value::as_arr)
            .and_then(|arr| arr.first())
            .and_then(|b| b.get("state").and_then(Value::as_str))
            .unwrap_or("")
            .to_string();
        if state == "up" {
            break;
        }
        assert!(Instant::now() < deadline, "prober never admitted b0 ('{state}')");
        std::thread::sleep(Duration::from_millis(25));
    }
    for i in 0..60 {
        let resp = post_predict(&mut gc)
            .unwrap_or_else(|e| panic!("gateway request {i} hung/died: {e}"));
        if resp.status == 200 {
            continue;
        }
        let code = error_code(&resp)
            .unwrap_or_else(|| panic!("gateway request {i}: untyped {}", resp.status));
        assert!(
            matches!(
                code.as_str(),
                "exec.worker_crashed" | "exec.circuit_open" | "gateway.no_backend"
            ),
            "gateway request {i}: unexpected error code '{code}'"
        );
    }
    assert!(
        chaos::global().unwrap().injected(chaos::GATEWAY_CONNECT) > 0,
        "gateway.connect site never injected over 60 requests at 30%"
    );

    // Recovery: disarm the plane; the breaker must walk open → half-open
    // probe → closed on live traffic, and then stay clean.
    chaos::set_armed(false);
    std::thread::sleep(Duration::from_millis(300));
    let deadline = Instant::now() + Duration::from_secs(10);
    while breakers.state_of(&key) != "closed" {
        assert!(
            Instant::now() < deadline,
            "breaker never recovered after disarm (state '{}')",
            breakers.state_of(&key)
        );
        let _ = post_predict(&mut c).unwrap();
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(metrics.counter("breaker_half_open_total") >= 1);
    assert!(metrics.counter("breaker_close_total") >= 1);
    for _ in 0..10 {
        assert_eq!(post_predict(&mut c).unwrap().status, 200);
    }

    // The counters all live in the one exposition handlers scrape.
    let prom = metrics.render_prometheus();
    for series in [
        "flexserve_chaos_inject_exec_device_total",
        "flexserve_chaos_inject_gateway_connect_total",
        "flexserve_breaker_open_total",
        "flexserve_breaker_close_total",
    ] {
        assert!(prom.contains(series), "missing series {series} in:\n{prom}");
    }
    gw.stop();
    backend.stop();
}
