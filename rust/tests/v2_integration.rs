//! End-to-end `/v2` (Open Inference Protocol) integration: metadata,
//! readiness, the infer data plane, the `_ensemble` alias, and the
//! differential guarantee that `/v2` serves IDENTICAL predictions to
//! `/v1` for the same tensor. One shared server per test binary (device
//! compile is ~6 s); membership-mutating tests take the write side of a
//! shared RwLock so read-only tests never observe a partial ensemble.

use flexserve::config::ServeConfig;
use flexserve::coordinator::{serve, SchedConfig, ServerState};
use flexserve::http::client::v2_infer_body;
use flexserve::http::{Client, Request, ServerHandle};
use flexserve::json::{self, Value};
use flexserve::util::Prng;
use flexserve::workload;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Duration;

/// Real artifacts when `make artifacts` produced them, else the seeded
/// synthetic CPU-backend set — this suite is always-on either way.
fn artifact_dir() -> PathBuf {
    flexserve::runtime::synth::ensure_artifacts()
}

struct Stack {
    handle: ServerHandle,
    state: Arc<ServerState>,
}

static STACK: OnceLock<Stack> = OnceLock::new();
/// Read for tests that assume the full 3-model membership; write for
/// tests that mutate it (and restore before releasing).
static MEMBERSHIP: RwLock<()> = RwLock::new(());

fn stack() -> &'static Stack {
    STACK.get_or_init(|| {
        let mut config = ServeConfig::default();
        config.addr = "127.0.0.1:0".into();
        config.artifacts = artifact_dir();
        config.http_workers = 4;
        config.device_workers = 1;
        config.warmup = false;
        config.scheduler = Some(SchedConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(1),
            adaptive: false,
            ..Default::default()
        });
        let (handle, state) = serve(&config).expect("server starts");
        Stack { handle, state }
    })
}

fn client() -> Client {
    Client::connect(stack().handle.addr).unwrap()
}

fn make_tensor(batch: usize, seed: u64) -> Vec<f32> {
    let mut rng = Prng::new(seed);
    let (data, _) = workload::make_batch(&mut rng, batch);
    data
}

fn v2_error_string(r: &flexserve::http::Response) -> String {
    r.json_body()
        .unwrap()
        .get("error")
        .and_then(Value::as_str)
        .unwrap_or("<none>")
        .to_string()
}

// ---------------------------------------------------------------------------
// Metadata + readiness
// ---------------------------------------------------------------------------

#[test]
fn v2_server_metadata_and_health() {
    let _g = MEMBERSHIP.read().unwrap();
    let mut c = client();

    let r = c.get("/v2").unwrap();
    assert_eq!(r.status, 200);
    let v = r.json_body().unwrap();
    assert_eq!(v.get("name").unwrap().as_str(), Some("flexserve"));
    assert!(v.get("version").unwrap().as_str().is_some());
    assert!(v.get("extensions").unwrap().as_arr().is_some());

    let r = c.get("/v2/health/live").unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(r.json_body().unwrap().get("live").unwrap().as_bool(), Some(true));

    assert!(c.v2_ready(None).unwrap(), "3 active models → server ready");
}

#[test]
fn v2_model_metadata_names_typed_shaped_io() {
    let _g = MEMBERSHIP.read().unwrap();
    let mut c = client();

    let v = c.v2_model_metadata("cnn_m").unwrap();
    assert_eq!(v.get("name").unwrap().as_str(), Some("cnn_m"));
    assert_eq!(v.get("platform").unwrap().as_str(), Some("flexserve-xla-pjrt"));
    // Input: FP32, dynamic batch + the manifest's sample shape.
    let input = v.get("inputs").unwrap().at(0).unwrap();
    assert_eq!(input.get("name").unwrap().as_str(), Some("input"));
    assert_eq!(input.get("datatype").unwrap().as_str(), Some("FP32"));
    let shape: Vec<i64> = input
        .get("shape")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|d| d.as_f64().unwrap() as i64)
        .collect();
    assert_eq!(shape, vec![-1, 16, 16, 1]);
    // Outputs: class names (BYTES) + probabilities (FP32).
    let outs = v.get("outputs").unwrap().as_arr().unwrap();
    assert_eq!(outs[0].get("name").unwrap().as_str(), Some("classes"));
    assert_eq!(outs[0].get("datatype").unwrap().as_str(), Some("BYTES"));
    assert_eq!(outs[1].get("name").unwrap().as_str(), Some("probs"));
    assert_eq!(outs[1].get("datatype").unwrap().as_str(), Some("FP32"));
    // Provenance rides as a custom field (the paper's motivating ask).
    assert!(!v
        .path(&["parameters", "params_sha256"])
        .unwrap()
        .as_str()
        .unwrap()
        .is_empty());
    assert_eq!(v.path(&["parameters", "state"]).unwrap().as_str(), Some("active"));

    // The ensemble pseudo-model lists per-model outputs.
    let v = c.v2_model_metadata("_ensemble").unwrap();
    let out_names: Vec<&str> = v
        .get("outputs")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|o| o.get("name").unwrap().as_str().unwrap())
        .collect();
    for model in ["cnn_m", "cnn_s", "mlp"] {
        assert!(out_names.contains(&format!("{model}.classes").as_str()), "{out_names:?}");
    }
    assert_eq!(v.path(&["parameters", "ensemble"]).unwrap().as_bool(), Some(true));

    // Unknown model: protocol-shaped error string with the taxonomy code.
    let r = c.get("/v2/models/resnet152").unwrap();
    assert_eq!(r.status, 404);
    assert_eq!(
        v2_error_string(&r),
        "model.unknown: unknown model 'resnet152'"
    );
}

#[test]
fn v2_model_readiness_tracks_lifecycle() {
    let _g = MEMBERSHIP.write().unwrap();
    let mut c = client();

    assert!(c.v2_ready(Some("cnn_s")).unwrap());
    assert!(c.v2_ready(Some("_ensemble")).unwrap());
    // Unknown model is a 404 error, not a false.
    assert!(c.v2_ready(Some("resnet152")).is_err());

    // Unload → 503 + ready:false; reload → ready again.
    c.unload_model("cnn_s").unwrap();
    assert!(!c.v2_ready(Some("cnn_s")).unwrap());
    let r = c.get("/v2/models/cnn_s/ready").unwrap();
    assert_eq!(r.status, 503);
    c.load_model("cnn_s").unwrap();
    assert!(c.v2_ready(Some("cnn_s")).unwrap());
}

// ---------------------------------------------------------------------------
// Infer data plane
// ---------------------------------------------------------------------------

/// The acceptance-criterion differential: `/v2` infer and `/v1` predict
/// return identical predictions for the same f32 tensor — single model
/// and ensemble alias both.
#[test]
fn v2_infer_matches_v1_predict_for_the_same_tensor() {
    let _g = MEMBERSHIP.read().unwrap();
    let mut c = client();

    for batch in [1, 3, 8] {
        let data = make_tensor(batch, 1000 + batch as u64);
        let shape = [batch, workload::IMG, workload::IMG, 1];

        // Single-model fast path.
        let v1_body = json::obj([
            ("data", json::f32_array_raw(data.iter().copied())),
            ("batch", Value::from(batch)),
        ]);
        let v1 = c
            .post_json("/v1/models/mlp/predict", &v1_body)
            .unwrap()
            .json_body()
            .unwrap();
        let v1_preds: Vec<String> = v1
            .get("predictions")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|p| p.as_str().unwrap().to_string())
            .collect();

        let v2 = c.v2_infer("mlp", &shape, &data).unwrap();
        assert_eq!(v2.get("model_name").unwrap().as_str(), Some("mlp"));
        let out = v2.get("outputs").unwrap().at(0).unwrap();
        assert_eq!(out.get("name").unwrap().as_str(), Some("classes"));
        assert_eq!(out.get("datatype").unwrap().as_str(), Some("BYTES"));
        assert_eq!(
            out.get("shape").unwrap().as_arr().unwrap()[0].as_usize(),
            Some(batch)
        );
        let v2_preds: Vec<String> = out
            .get("data")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|p| p.as_str().unwrap().to_string())
            .collect();
        assert_eq!(v1_preds, v2_preds, "batch {batch}: v1 and v2 must agree");

        // Ensemble: /v1/predict vs the _ensemble alias, every model.
        let v1 = c
            .post_json("/v1/predict", &v1_body)
            .unwrap()
            .json_body()
            .unwrap();
        let v2 = c.v2_infer("_ensemble", &shape, &data).unwrap();
        assert_eq!(v2.get("model_name").unwrap().as_str(), Some("_ensemble"));
        let outs = v2.get("outputs").unwrap().as_arr().unwrap();
        for model in ["cnn_m", "cnn_s", "mlp"] {
            let v1_preds: Vec<&str> = v1
                .get(&format!("model_{model}"))
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|p| p.as_str().unwrap())
                .collect();
            let out = outs
                .iter()
                .find(|o| o.get("name").unwrap().as_str() == Some(&format!("{model}.classes")))
                .unwrap_or_else(|| panic!("missing {model}.classes output"));
            let v2_preds: Vec<&str> = out
                .get("data")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|p| p.as_str().unwrap())
                .collect();
            assert_eq!(v1_preds, v2_preds, "{model} batch {batch}");
        }
    }
}

#[test]
fn v2_infer_dtypes_convert_at_the_boundary() {
    let _g = MEMBERSHIP.read().unwrap();
    let mut c = client();
    let batch = 2;
    let elems = workload::IMG * workload::IMG;
    // An integral-valued tensor is expressible in all three dtypes.
    let data: Vec<f32> = (0..batch * elems).map(|i| (i % 3) as f32).collect();
    let shape_doc = |dims: &[usize]| {
        Value::Arr(dims.iter().map(|&d| Value::from(d)).collect())
    };
    let body = |dtype: &str| {
        json::obj([(
            "inputs",
            Value::Arr(vec![json::obj([
                ("name", Value::from("input")),
                ("datatype", Value::from(dtype)),
                ("shape", shape_doc(&[batch, workload::IMG, workload::IMG, 1])),
                ("data", json::f32_array_raw(data.iter().copied())),
            ])]),
        )])
    };
    let preds_of = |v: &Value| -> Vec<String> {
        v.get("outputs")
            .unwrap()
            .at(0)
            .unwrap()
            .get("data")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|p| p.as_str().unwrap().to_string())
            .collect()
    };

    let fp32 = c
        .post_json("/v2/models/mlp/infer", &body("FP32"))
        .unwrap()
        .json_body()
        .unwrap();
    for dtype in ["INT64", "UINT8"] {
        let r = c.post_json("/v2/models/mlp/infer", &body(dtype)).unwrap();
        assert_eq!(r.status, 200, "{dtype}: {}", String::from_utf8_lossy(&r.body));
        assert_eq!(
            preds_of(&r.json_body().unwrap()),
            preds_of(&fp32),
            "{dtype} must predict identically to FP32 for integral data"
        );
    }

    // BYTES and unknown dtypes reject with the bad_input.dtype code.
    let bad = json::obj([(
        "inputs",
        Value::Arr(vec![json::obj([
            ("name", Value::from("input")),
            ("datatype", Value::from("BYTES")),
            ("shape", shape_doc(&[1, elems])),
            ("data", Value::Arr(vec![Value::from("x"); elems])),
        ])]),
    )]);
    let r = c.post_json("/v2/models/mlp/infer", &bad).unwrap();
    assert_eq!(r.status, 422);
    assert_eq!(
        v2_error_string(&r),
        "bad_input.dtype: tensor 'input': BYTES input is not supported \
         (model takes a numeric tensor)"
    );
}

#[test]
fn v2_infer_parameters_outputs_and_id() {
    let _g = MEMBERSHIP.read().unwrap();
    let mut c = client();
    let data = make_tensor(2, 77);
    let body = json::obj([
        ("id", Value::from("req-42")),
        (
            "inputs",
            Value::Arr(vec![json::obj([
                ("name", Value::from("input")),
                ("datatype", Value::from("FP32")),
                (
                    "shape",
                    Value::Arr(vec![
                        Value::from(2usize),
                        Value::from(workload::IMG),
                        Value::from(workload::IMG),
                        Value::from(1usize),
                    ]),
                ),
                ("data", json::f32_array_raw(data.iter().copied())),
            ])]),
        ),
        (
            "parameters",
            json::obj([
                ("detail", Value::Bool(true)),
                ("policy", Value::from("any")),
                ("target", Value::from("cross")),
            ]),
        ),
    ]);
    let r = c.post_json("/v2/models/_ensemble/infer", &body).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json_body().unwrap();
    assert_eq!(v.get("id").unwrap().as_str(), Some("req-42"));
    // detail → per-stage timings in the response parameters.
    assert!(v.path(&["parameters", "exec_us"]).is_some());
    let outs = v.get("outputs").unwrap().as_arr().unwrap();
    let names: Vec<&str> = outs
        .iter()
        .map(|o| o.get("name").unwrap().as_str().unwrap())
        .collect();
    // detail adds per-model probs; policy+target adds BOOL detections.
    assert!(names.contains(&"mlp.probs"), "{names:?}");
    let det = outs
        .iter()
        .find(|o| o.get("name").unwrap().as_str() == Some("detections"))
        .expect("detections output present");
    assert_eq!(det.get("datatype").unwrap().as_str(), Some("BOOL"));
    assert_eq!(det.get("data").unwrap().as_arr().unwrap().len(), 2);

    // Output selection: only the requested tensor comes back.
    let mut sel = match body {
        Value::Obj(m) => m,
        _ => unreachable!(),
    };
    sel.push((
        "outputs".to_string(),
        Value::Arr(vec![json::obj([("name", Value::from("mlp.classes"))])]),
    ));
    let v = c
        .post_json("/v2/models/_ensemble/infer", &Value::Obj(sel))
        .unwrap()
        .json_body()
        .unwrap();
    let outs = v.get("outputs").unwrap().as_arr().unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].get("name").unwrap().as_str(), Some("mlp.classes"));

    // Unknown requested output is a typed 422.
    let data2 = make_tensor(1, 5);
    let mut bad = match v2_infer_body(&[1, workload::IMG, workload::IMG, 1], &data2) {
        Value::Obj(m) => m,
        _ => unreachable!(),
    };
    bad.push((
        "outputs".to_string(),
        Value::Arr(vec![json::obj([("name", Value::from("nope"))])]),
    ));
    let r = c
        .post_json("/v2/models/_ensemble/infer", &Value::Obj(bad))
        .unwrap();
    assert_eq!(r.status, 422);
    assert_eq!(v2_error_string(&r), "bad_input.bad_value: unknown output 'nope'");
}

#[test]
fn v2_infer_errors_are_protocol_shaped() {
    let _g = MEMBERSHIP.read().unwrap();
    let mut c = client();
    let data = make_tensor(1, 9);

    // Unknown model → 404; same status taxonomy as /v1, OIP error shape.
    let r = c.post_json(
        "/v2/models/resnet152/infer",
        &v2_infer_body(&[1, workload::IMG, workload::IMG, 1], &data),
    );
    let r = r.unwrap();
    assert_eq!(r.status, 404);
    assert!(v2_error_string(&r).starts_with("model.unknown: "));

    // Malformed JSON → 400.
    let r = c.post("/v2/models/mlp/infer", b"not json".to_vec()).unwrap();
    assert_eq!(r.status, 400);
    assert!(v2_error_string(&r).starts_with("bad_input.malformed_json: "));

    // Shape mismatch → 422 with the stable string.
    let r = c
        .post_json("/v2/models/mlp/infer", &v2_infer_body(&[1, 3, 3], &data))
        .unwrap();
    assert_eq!(r.status, 422);
    assert!(v2_error_string(&r).starts_with("bad_input.shape_mismatch: "));

    // Method mismatch on a /v2 route → 405 with an Allow header.
    let r = c.get("/v2/models/mlp/infer").unwrap();
    assert_eq!(r.status, 405);
    assert_eq!(r.header("allow"), Some("POST"));
    // And on /v1 (multi-method path): PUT+GET /v1/ensemble.
    let r = c
        .request(&Request::new("DELETE", "/v1/ensemble", Vec::new()))
        .unwrap();
    assert_eq!(r.status, 405);
    assert_eq!(r.header("allow"), Some("GET, PUT"));
}

#[test]
fn v2_requests_feed_the_shared_metrics_and_prometheus_exposition() {
    // Write side: the rows_total before/after window must not race other
    // tests' data-plane traffic.
    let _g = MEMBERSHIP.write().unwrap();
    let mut c = client();
    let data = make_tensor(1, 31);
    let before = stack().state.metrics.counter("rows_total");
    let _ = c
        .v2_infer("mlp", &[1, workload::IMG, workload::IMG, 1], &data)
        .unwrap();
    assert_eq!(stack().state.metrics.counter("rows_total"), before + 1);

    // The Prometheus exposition serves scrapers (explicit format or
    // Accept negotiation) while the default text stays byte-stable.
    let r = c.get("/v1/metrics?format=prometheus").unwrap();
    assert_eq!(r.status, 200);
    assert!(r.header("content-type").unwrap().contains("version=0.0.4"));
    let text = String::from_utf8(r.body).unwrap();
    assert!(text.contains("# TYPE flexserve_requests_total counter"), "{text}");
    assert!(text.contains("quantile=\"0.99\""), "{text}");
    assert!(text.contains("flexserve_route_v2_models__name_infer_us_count"), "{text}");

    let mut req = Request::new("GET", "/v1/metrics", Vec::new());
    req.headers
        .push(("accept".into(), "text/plain;version=0.0.4".into()));
    let r = c.request(&req).unwrap();
    assert!(String::from_utf8(r.body).unwrap().contains("# TYPE"), "Accept negotiation");

    // Legacy default exposition unchanged (no comment lines).
    let r = c.get("/v1/metrics").unwrap();
    let text = String::from_utf8(r.body).unwrap();
    assert!(!text.contains("# TYPE"), "default stays the legacy text");
    assert!(text.contains("flexserve_requests_total"));
}
