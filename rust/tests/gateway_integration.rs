//! End-to-end gateway integration.
//!
//! Device-free tests (always run) drive a REAL gateway over fake replicas
//! that speak enough of the /v1 + /v2 wire to check the tier's core
//! guarantees: single-shard requests proxy byte-identically, ensembles
//! spanning shards merge to exactly what one process would have said,
//! killed replicas are survived (rerouted 200s or a typed
//! `gateway.no_backend` 503 — never a hang), and an empty fleet answers
//! the typed 503.
//!
//! The full-stack differential (always-on: real artifacts when present,
//! else the synthetic CPU-backend set) runs TWO `serve` stacks behind a gateway
//! whose backend ids are chosen so the ring splits the three models
//! across both processes, then asserts gateway responses are
//! byte-identical to a direct backend hit for both wire formats.

use flexserve::config::{GatewayConfig, ServeConfig};
use flexserve::coordinator::infer::fuse_named_votes;
use flexserve::coordinator::{serve, Policy, SchedConfig};
use flexserve::gateway::ring::{route_key, Ring};
use flexserve::gateway::{self, scatter};
use flexserve::http::{Client, Request, Response, Server, ServerHandle};
use flexserve::json::{self, Value};
use flexserve::util::Prng;
use flexserve::workload;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Real artifacts when `make artifacts` produced them, else the seeded
/// synthetic CPU-backend set — the differential test is always-on either way.
fn artifact_dir() -> PathBuf {
    flexserve::runtime::synth::ensure_artifacts()
}

// ---------------------------------------------------------------------------
// Device-free fixtures
// ---------------------------------------------------------------------------

/// Deterministic fake prediction, identical on every replica — the merge
/// differential depends on subsets and full sets agreeing row by row.
fn fake_class(model: &str, row: usize) -> &'static str {
    let sum: usize = model.bytes().map(|b| b as usize).sum();
    if (sum + row) % 3 == 0 {
        "cross"
    } else {
        "blank"
    }
}

/// A device-free replica speaking the subset of the real wire the gateway
/// exercises: the readiness probe, `/v1/predict`, and the `/v2` ensemble
/// infer route. Predictions come from [`fake_class`]; fusion reuses the
/// coordinator's own `fuse_named_votes`, so a direct hit and a gateway
/// merge disagree only if the gateway is wrong.
fn fake_backend(models: &'static [&'static str]) -> ServerHandle {
    Server::spawn(
        "127.0.0.1:0",
        4,
        Arc::new(move |req: &Request| {
            if req.method == "GET" && req.path == "/v1/healthz" {
                return Response::json(
                    200,
                    &json::obj([
                        ("status", Value::from("ok")),
                        ("ready", Value::from(true)),
                        (
                            "active",
                            Value::Arr(models.iter().map(|m| Value::from(*m)).collect()),
                        ),
                    ]),
                );
            }
            if req.method == "POST" && (req.path == "/v1/predict" || req.path == "/predict") {
                return fake_v1_predict(req, models);
            }
            if req.method == "POST" && req.path == "/v2/models/_ensemble/infer" {
                return fake_v2_infer(req, models);
            }
            Response::coded_error(404, "route.not_found", "fake backend")
        }),
    )
    .unwrap()
}

fn fake_v1_predict(req: &Request, active: &[&str]) -> Response {
    let params = match scatter::v1_params(req) {
        Ok(p) => p,
        Err(()) => return Response::coded_error(400, "bad_input.malformed_json", "not json"),
    };
    let members = params
        .members
        .unwrap_or_else(|| active.iter().map(|m| m.to_string()).collect());
    let batch = req
        .json_body()
        .ok()
        .and_then(|b| b.get("batch").and_then(Value::as_usize))
        .unwrap_or(2);
    let mut named: Vec<(String, Vec<String>)> = Vec::with_capacity(members.len());
    let mut doc: Vec<(String, Value)> = Vec::with_capacity(members.len() + 1);
    for m in &members {
        let rows: Vec<String> = (0..batch).map(|i| fake_class(m, i).to_string()).collect();
        doc.push((
            format!("model_{m}"),
            Value::Arr(rows.iter().map(|r| Value::from(r.as_str())).collect()),
        ));
        named.push((m.clone(), rows));
    }
    if let (Some(p), Some(t)) = (&params.policy, &params.target) {
        let policy = Policy::parse(p).unwrap();
        let detections: Vec<Value> = fuse_named_votes(&named, &policy, t)
            .unwrap()
            .into_iter()
            .map(Value::Bool)
            .collect();
        doc.push((
            "ensemble".to_string(),
            json::obj([
                ("policy", Value::from(policy.to_string())),
                ("target", Value::from(t.as_str())),
                ("detections", Value::Arr(detections)),
            ]),
        ));
    }
    Response::json(200, &Value::Obj(doc))
}

fn fake_v2_infer(req: &Request, active: &[&str]) -> Response {
    let body = match req.json_body() {
        Ok(b) => b,
        Err(_) => return Response::coded_error(400, "bad_input.malformed_json", "not json"),
    };
    let params = scatter::v2_params(&body);
    let members = params
        .members
        .unwrap_or_else(|| active.iter().map(|m| m.to_string()).collect());
    let batch = body
        .path(&["inputs"])
        .and_then(|v| v.as_arr())
        .and_then(|arr| arr.first())
        .and_then(|t| t.get("shape"))
        .and_then(|s| s.as_arr())
        .and_then(|s| s.first())
        .and_then(Value::as_usize)
        .unwrap_or(1);
    let mut named: Vec<(String, Vec<String>)> = Vec::with_capacity(members.len());
    let mut outputs: Vec<Value> = Vec::with_capacity(members.len() + 1);
    for m in &members {
        let rows: Vec<String> = (0..batch).map(|i| fake_class(m, i).to_string()).collect();
        outputs.push(json::obj([
            ("name", Value::from(format!("{m}.classes"))),
            ("datatype", Value::from("BYTES")),
            ("shape", Value::Arr(vec![Value::from(batch)])),
            (
                "data",
                Value::Arr(rows.iter().map(|r| Value::from(r.as_str())).collect()),
            ),
        ]));
        named.push((m.clone(), rows));
    }
    if let (Some(p), Some(t)) = (&params.policy, &params.target) {
        let policy = Policy::parse(p).unwrap();
        let detections: Vec<Value> = fuse_named_votes(&named, &policy, t)
            .unwrap()
            .into_iter()
            .map(Value::Bool)
            .collect();
        outputs.push(json::obj([
            ("name", Value::from("detections")),
            ("datatype", Value::from("BOOL")),
            ("shape", Value::Arr(vec![Value::from(batch)])),
            ("data", Value::Arr(detections)),
        ]));
    }
    let served: Vec<String> = members.iter().map(|m| format!("{m}:1")).collect();
    let mut doc: Vec<(String, Value)> = vec![
        ("model_name".to_string(), Value::from("_ensemble")),
        ("model_version".to_string(), Value::from("1")),
    ];
    if let Some(id) = &params.id {
        doc.push(("id".to_string(), Value::from(id.as_str())));
    }
    doc.push((
        "parameters".to_string(),
        json::obj([("served_versions", Value::from(served.join(",")))]),
    ));
    doc.push(("outputs".to_string(), Value::Arr(outputs)));
    Response::json(200, &Value::Obj(doc))
}

/// Gateway config over already-running backends, probe cadence tightened
/// for test latency.
fn gateway_cfg(ids: &[String], handles: &[&ServerHandle]) -> GatewayConfig {
    let mut cfg = GatewayConfig::default();
    cfg.addr = "127.0.0.1:0".into();
    cfg.backends = ids
        .iter()
        .zip(handles)
        .map(|(id, h)| (id.clone(), h.addr.to_string()))
        .collect();
    cfg.probe_interval = Duration::from_millis(50);
    cfg.probe_timeout = Duration::from_millis(250);
    cfg.fail_after = 2;
    cfg.rise_after = 1;
    cfg.retry_budget = 1;
    cfg
}

/// Backend ids whose ring placement splits `models` across both of two
/// backends — found with the same pure `Ring` the gateway uses, so the
/// test controls sharding without ever guessing hash values.
fn splitting_ids(models: &[&str], vnodes: usize) -> Vec<String> {
    for salt in 0..1000 {
        let ids = vec![format!("a{salt}"), format!("b{salt}")];
        let ring = Ring::new(&ids, vnodes);
        let owners: Vec<usize> = models
            .iter()
            .map(|m| ring.owner(&route_key(m, None)).unwrap())
            .collect();
        if owners.iter().any(|&o| o == 0) && owners.iter().any(|&o| o == 1) {
            return ids;
        }
    }
    panic!("no splitting id pair found in 1000 salts");
}

/// Ids that place every one of `models` on backend 0 of two — the
/// single-shard collapse case.
fn colocating_ids(models: &[&str], vnodes: usize) -> Vec<String> {
    for salt in 0..10_000 {
        let ids = vec![format!("a{salt}"), format!("b{salt}")];
        let ring = Ring::new(&ids, vnodes);
        if models
            .iter()
            .all(|m| ring.owner(&route_key(m, None)) == Some(0))
        {
            return ids;
        }
    }
    panic!("no colocating id pair found in 10000 salts");
}

fn wait_backend_state(c: &mut Client, id: &str, want: &str) {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let doc = c.get("/v1/gateway").unwrap().json_body().unwrap();
        let state = doc
            .get("backends")
            .and_then(Value::as_arr)
            .and_then(|arr| {
                arr.iter()
                    .find(|b| b.get("id").and_then(Value::as_str) == Some(id))
            })
            .and_then(|b| b.get("state").and_then(Value::as_str))
            .unwrap_or("")
            .to_string();
        if state == want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "backend {id} never reached '{want}' (at '{state}')"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

// ---------------------------------------------------------------------------
// Device-free: byte fidelity
// ---------------------------------------------------------------------------

/// Single-shard requests (here: every member colocated by construction)
/// forward verbatim — the gateway body is byte-identical to a direct
/// backend hit, for both wire formats.
#[test]
fn single_shard_proxying_is_byte_identical() {
    const MODELS: [&'static str; 3] = ["m1", "m2", "m3"];
    let b0 = fake_backend(&MODELS);
    let b1 = fake_backend(&MODELS);
    let ids = colocating_ids(&MODELS, 64);
    let gw = gateway::spawn(gateway_cfg(&ids, &[&b0, &b1])).unwrap();
    let mut via_gw = Client::connect(gw.server.addr).unwrap();
    let mut direct = Client::connect(b0.addr).unwrap();

    // /v1: query carries the members, body carries batch + fusion knobs.
    let path = "/v1/predict?models=m1,m2,m3";
    let body = br#"{"batch": 4, "policy": "majority", "target": "cross"}"#.to_vec();
    let g = via_gw
        .request(&Request::new("POST", path, body.clone()))
        .unwrap();
    let d = direct.request(&Request::new("POST", path, body)).unwrap();
    assert_eq!(g.status, 200, "{}", String::from_utf8_lossy(&g.body));
    assert_eq!(d.status, 200);
    assert_eq!(g.body, d.body, "v1 proxy must be byte-identical");
    assert_eq!(
        g.header("x-flexserve-backend"),
        Some(ids[0].as_str()),
        "response tags the serving replica"
    );

    // /v2: everything rides in the body.
    let v2_body = br#"{"id":"rq-1","inputs":[{"name":"input","datatype":"FP32","shape":[3,4],"data":[0,0,0,0,0,0,0,0,0,0,0,0]}],"parameters":{"models":"m1,m2,m3","policy":"any","target":"cross"}}"#.to_vec();
    let g = via_gw
        .request(&Request::new(
            "POST",
            "/v2/models/_ensemble/infer",
            v2_body.clone(),
        ))
        .unwrap();
    let d = direct
        .request(&Request::new("POST", "/v2/models/_ensemble/infer", v2_body))
        .unwrap();
    assert_eq!(g.status, 200, "{}", String::from_utf8_lossy(&g.body));
    assert_eq!(g.body, d.body, "v2 proxy must be byte-identical");

    gw.stop();
    b0.stop();
    b1.stop();
}

/// The scatter-gather differential: an ensemble split across two shards
/// merges into byte-for-byte the same answer one process gives for the
/// whole ensemble — member order, recomputed fusion, provenance and all.
#[test]
fn scatter_gather_matches_single_process_byte_for_byte() {
    const MODELS: [&'static str; 3] = ["m1", "m2", "m3"];
    let b0 = fake_backend(&MODELS);
    let b1 = fake_backend(&MODELS);
    let ids = splitting_ids(&MODELS, 64);
    let gw = gateway::spawn(gateway_cfg(&ids, &[&b0, &b1])).unwrap();
    let mut via_gw = Client::connect(gw.server.addr).unwrap();
    let mut direct = Client::connect(b0.addr).unwrap();

    for (policy, target) in [("majority", "cross"), ("atleast:2", "blank"), ("any", "cross")] {
        let path = format!("/v1/predict?models=m1,m2,m3&policy={policy}&target={target}");
        let body = br#"{"batch": 5}"#.to_vec();
        let g = via_gw
            .request(&Request::new("POST", &path, body.clone()))
            .unwrap();
        let d = direct.request(&Request::new("POST", &path, body)).unwrap();
        assert_eq!(g.status, 200, "{}", String::from_utf8_lossy(&g.body));
        assert_eq!(
            g.body, d.body,
            "{policy}/{target}: scattered v1 ensemble must equal one process"
        );
    }

    let v2_body = br#"{"id":"rq-7","inputs":[{"name":"input","datatype":"FP32","shape":[4,2],"data":[0,0,0,0,0,0,0,0]}],"parameters":{"models":"m1,m2,m3","policy":"majority","target":"cross"}}"#.to_vec();
    let g = via_gw
        .request(&Request::new(
            "POST",
            "/v2/models/_ensemble/infer",
            v2_body.clone(),
        ))
        .unwrap();
    let d = direct
        .request(&Request::new("POST", "/v2/models/_ensemble/infer", v2_body))
        .unwrap();
    assert_eq!(g.status, 200, "{}", String::from_utf8_lossy(&g.body));
    assert_eq!(
        g.body, d.body,
        "scattered v2 ensemble must equal one process"
    );

    // The gateway counted the fan-out.
    assert!(gw.gateway.metrics.counter("gw_scatter_total") >= 4);

    gw.stop();
    b0.stop();
    b1.stop();
}

// ---------------------------------------------------------------------------
// Device-free: failure handling
// ---------------------------------------------------------------------------

/// Killing a replica mid-run never hangs a request: every answer is a
/// rerouted 200 from the survivor or (once the whole fleet is gone and
/// ejected) the typed `gateway.no_backend` 503.
#[test]
fn killed_backend_reroutes_then_types_503() {
    const MODELS: [&'static str; 1] = ["solo"];
    let b0 = fake_backend(&MODELS);
    let b1 = fake_backend(&MODELS);
    let ids = vec!["r0".to_string(), "r1".to_string()];
    let gw = gateway::spawn(gateway_cfg(&ids, &[&b0, &b1])).unwrap();
    let mut c = Client::connect(gw.server.addr).unwrap();

    let owner = Ring::new(&ids, 64)
        .owner(&route_key("solo", None))
        .unwrap();
    let (victim_handle, victim_id) = if owner == 0 {
        (&b0, &ids[0])
    } else {
        (&b1, &ids[1])
    };

    let predict = |c: &mut Client| {
        c.request(&Request::new(
            "POST",
            "/v1/predict?models=solo",
            br#"{"batch": 1}"#.to_vec(),
        ))
        .unwrap()
    };
    for _ in 0..5 {
        assert_eq!(predict(&mut c).status, 200);
    }

    // Kill the owner mid-run: every subsequent answer must still be a 200
    // (failover walks to the survivor on transport error) — and once the
    // prober ejects the corpse, traffic must tag the survivor.
    victim_handle.stop();
    for _ in 0..20 {
        let resp = predict(&mut c);
        assert_eq!(
            resp.status, 200,
            "mid-kill request failed: {}",
            String::from_utf8_lossy(&resp.body)
        );
    }
    wait_backend_state(&mut c, victim_id, "down");
    let resp = predict(&mut c);
    assert_eq!(resp.status, 200);
    assert_ne!(
        resp.header("x-flexserve-backend"),
        Some(victim_id.as_str()),
        "ejected replica must not serve"
    );

    // Kill the survivor too: after ejection the gateway answers the typed
    // 503 immediately — no hang, no transport error leak.
    let (survivor_handle, survivor_id) = if owner == 0 {
        (&b1, &ids[1])
    } else {
        (&b0, &ids[0])
    };
    survivor_handle.stop();
    wait_backend_state(&mut c, survivor_id, "down");
    let resp = predict(&mut c);
    assert_eq!(resp.status, 503);
    let err = resp.json_body().unwrap();
    assert_eq!(
        err.path(&["error", "code"]).and_then(Value::as_str),
        Some("gateway.no_backend"),
        "{err}"
    );
    assert!(resp.header("retry-after").is_some(), "hint the caller back");

    // The gateway's own readiness now reports the dead fleet.
    let resp = c.get("/v1/healthz").unwrap();
    assert_eq!(resp.status, 503);

    gw.stop();
}

/// Model-keyed control-plane routes stick to the model's shard and
/// gateway-local introspection answers without backends.
#[test]
fn model_keyed_routes_stick_and_introspection_is_local() {
    const MODELS: [&'static str; 3] = ["m1", "m2", "m3"];
    let b0 = fake_backend(&MODELS);
    let b1 = fake_backend(&MODELS);
    let ids = vec!["r0".to_string(), "r1".to_string()];
    let gw = gateway::spawn(gateway_cfg(&ids, &[&b0, &b1])).unwrap();
    let mut c = Client::connect(gw.server.addr).unwrap();

    // The fake 404s unknown routes; what we assert is WHICH replica the
    // gateway picked — the ring owner, on every repeat.
    let owner = Ring::new(&ids, 64).owner(&route_key("m2", None)).unwrap();
    for _ in 0..5 {
        let resp = c
            .request(&Request::new("GET", "/v1/models/m2", Vec::new()))
            .unwrap();
        assert_eq!(
            resp.header("x-flexserve-backend"),
            Some(ids[owner].as_str()),
            "model-keyed route must stick to the ring owner"
        );
    }

    // /v1/gateway: ring facts + per-backend docs, no backend round-trip.
    let doc = c.get("/v1/gateway").unwrap().json_body().unwrap();
    assert_eq!(doc.path(&["ring", "backends"]).and_then(Value::as_u64), Some(2));
    assert_eq!(doc.path(&["ring", "vnodes"]).and_then(Value::as_u64), Some(64));
    assert_eq!(
        doc.get("backends").and_then(Value::as_arr).map(<[Value]>::len),
        Some(2)
    );

    // /livez answers even with no backend knowledge at all.
    let live = c.get("/v1/livez").unwrap();
    assert_eq!(live.status, 200);

    // Prometheus exposition carries the per-backend series. The state
    // gauge is first written by the prober, so poll past the first
    // ~50ms round before asserting.
    let deadline = Instant::now() + Duration::from_secs(5);
    let text = loop {
        let text = String::from_utf8(
            c.get("/v1/metrics?format=prometheus").unwrap().body,
        )
        .unwrap();
        if text.contains("flexserve_gw_backend_r0_state")
            || Instant::now() >= deadline
        {
            break text;
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    assert!(text.contains("flexserve_gw_requests_total"), "{text}");
    assert!(text.contains("flexserve_gw_backend_r0_state"), "{text}");

    gw.stop();
    b0.stop();
    b1.stop();
}

/// Tenant credentials survive the hop: the gateway forwards
/// `Authorization` and `x-api-key` verbatim (it strips only hop-by-hop
/// headers), so a keyed fleet authenticates end-to-end without the
/// gateway holding any keys. `/v1/gateway` also reports each backend's
/// `sheds` counter for the tier's per-replica shed story.
#[test]
fn auth_headers_pass_through_and_sheds_reported() {
    let echo = Server::spawn(
        "127.0.0.1:0",
        2,
        Arc::new(|req: &Request| {
            if req.method == "GET" && req.path == "/v1/healthz" {
                return Response::json(
                    200,
                    &json::obj([
                        ("status", Value::from("ok")),
                        ("ready", Value::from(true)),
                        ("active", Value::Arr(vec![Value::from("m1")])),
                    ]),
                );
            }
            Response::json(
                200,
                &json::obj([
                    (
                        "authorization",
                        Value::from(req.header("authorization").unwrap_or("")),
                    ),
                    (
                        "x_api_key",
                        Value::from(req.header("x-api-key").unwrap_or("")),
                    ),
                ]),
            )
        }),
    )
    .unwrap();
    let ids = vec!["r0".to_string()];
    let gw = gateway::spawn(gateway_cfg(&ids, &[&echo])).unwrap();
    let mut c = Client::connect(gw.server.addr).unwrap();

    let mut req = Request::new("POST", "/v1/predict?models=m1", br#"{"batch":1}"#.to_vec());
    req.headers
        .push(("authorization".into(), "Bearer sk-tenant".into()));
    req.headers.push(("x-api-key".into(), "acme-key".into()));
    let resp = c.request(&req).unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let doc = resp.json_body().unwrap();
    assert_eq!(
        doc.get("authorization").and_then(Value::as_str),
        Some("Bearer sk-tenant"),
        "Authorization must reach the backend untouched: {doc}"
    );
    assert_eq!(
        doc.get("x_api_key").and_then(Value::as_str),
        Some("acme-key"),
        "x-api-key must reach the backend untouched: {doc}"
    );

    // Introspection carries the per-backend shed counter (zero here — no
    // replica was ever skipped at its in-flight cap).
    let doc = c.get("/v1/gateway").unwrap().json_body().unwrap();
    let sheds = doc
        .get("backends")
        .and_then(Value::as_arr)
        .and_then(|arr| arr.first())
        .and_then(|b| b.get("sheds"))
        .and_then(Value::as_u64);
    assert_eq!(sheds, Some(0), "{doc}");

    gw.stop();
    echo.stop();
}

// ---------------------------------------------------------------------------
// Device-backed differential
// ---------------------------------------------------------------------------

/// Two REAL serving stacks behind the gateway, ring forced to split the
/// three models across them: the gateway must be byte-invisible for both
/// protocols, scatter-gather included.
#[test]
fn gateway_over_real_backends_is_byte_invisible() {
    let spawn_stack = || {
        let mut config = ServeConfig::default();
        config.addr = "127.0.0.1:0".into();
        config.artifacts = artifact_dir();
        config.http_workers = 4;
        config.device_workers = 1;
        config.warmup = false;
        config.scheduler = Some(SchedConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(1),
            adaptive: false,
            ..Default::default()
        });
        serve(&config).expect("server starts")
    };
    let (h0, _s0) = spawn_stack();
    let (h1, _s1) = spawn_stack();

    let models = ["cnn_m", "cnn_s", "mlp"];
    let ids = splitting_ids(&models, 64);
    let gw = gateway::spawn(gateway_cfg(&ids, &[&h0, &h1])).unwrap();
    let mut via_gw = Client::connect(gw.server.addr).unwrap();
    let mut direct = Client::connect(h0.addr).unwrap();

    let mut rng = Prng::new(4242);
    let batch = 3;
    let (data, _) = workload::make_batch(&mut rng, batch);

    // /v1 with fusion, detail off (detail adds gateway-only diagnostics
    // by design, so byte-fidelity is asserted on the paper wire format).
    let path = "/v1/predict?models=cnn_m,cnn_s,mlp&policy=majority&target=cross";
    let body = json::to_string(&json::obj([
        ("data", json::f32_array_raw(data.iter().copied())),
        ("batch", Value::from(batch)),
    ]))
    .into_bytes();
    let g = via_gw
        .request(&Request::new("POST", path, body.clone()))
        .unwrap();
    let d = direct.request(&Request::new("POST", path, body)).unwrap();
    assert_eq!(g.status, 200, "{}", String::from_utf8_lossy(&g.body));
    assert_eq!(d.status, 200);
    assert_eq!(g.body, d.body, "v1: gateway must be byte-invisible");

    // /v2 ensemble infer with fusion.
    let v2_body = json::to_string(&json::obj([
        ("id", Value::from("diff-1")),
        (
            "inputs",
            Value::Arr(vec![json::obj([
                ("name", Value::from("input")),
                ("datatype", Value::from("FP32")),
                (
                    "shape",
                    Value::Arr(vec![
                        Value::from(batch),
                        Value::from(workload::IMG),
                        Value::from(workload::IMG),
                        Value::from(1usize),
                    ]),
                ),
                ("data", json::f32_array_raw(data.iter().copied())),
            ])]),
        ),
        (
            "parameters",
            json::obj([
                ("models", Value::from("cnn_m,cnn_s,mlp")),
                ("policy", Value::from("majority")),
                ("target", Value::from("cross")),
            ]),
        ),
    ]))
    .into_bytes();
    let g = via_gw
        .request(&Request::new(
            "POST",
            "/v2/models/_ensemble/infer",
            v2_body.clone(),
        ))
        .unwrap();
    let d = direct
        .request(&Request::new("POST", "/v2/models/_ensemble/infer", v2_body))
        .unwrap();
    assert_eq!(g.status, 200, "{}", String::from_utf8_lossy(&g.body));
    assert_eq!(g.body, d.body, "v2: gateway must be byte-invisible");

    gw.stop();
    h0.stop();
    h1.stop();
}
