//! Integration tests: the Rust runtime against real artifacts when `make
//! artifacts` has produced them (validating the whole AOT bridge —
//! jax/pallas lowering → HLO text → PJRT compile → execute), and against
//! the synthetic CPU-backend set otherwise. Every test here runs in a
//! device-free CI environment; only the trained-numerics check still
//! requires the real zoo.

use flexserve::runtime::executor::ExecutorOptions;
use flexserve::runtime::{synth, ExecRequest, Executor, ExecutorPool, Manifest};
use flexserve::runtime::tensor::argmax_rows;
use flexserve::util::Prng;
use std::path::PathBuf;
use std::sync::Arc;

fn artifact_dir() -> PathBuf {
    // Tests run from the crate root.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn has_artifacts() -> bool {
    artifact_dir().join("manifest.json").exists()
}

/// Tests that need TRAINED models (real accuracy, real class structure)
/// skip rather than fail when `make artifacts` has not run; everything
/// else falls back to the synthetic CPU-backend artifacts and always runs.
macro_rules! require_artifacts {
    () => {
        if !has_artifacts() {
            eprintln!("skipping: artifacts missing — run `make artifacts` first");
            return;
        }
    };
}

fn manifest() -> Arc<Manifest> {
    Arc::new(Manifest::load(synth::ensure_artifacts()).expect("manifest loads"))
}

/// Synthetic frame batch shaped like the real dataset (normalized noise).
fn noise_batch(m: &Manifest, batch: usize, seed: u64) -> Vec<f32> {
    let mut rng = Prng::new(seed);
    (0..batch * m.sample_elems())
        .map(|_| rng.normal() as f32 * 0.35)
        .collect()
}

#[test]
fn manifest_loads_and_verifies() {
    let m = manifest();
    assert_eq!(m.input_shape, vec![16, 16, 1]);
    assert_eq!(m.num_classes(), 4);
    assert_eq!(m.models.len(), 3);
    assert!(m.buckets.contains(&1) && m.buckets.contains(&32));
    // Full provenance gate: every artifact hash must match.
    m.verify_all().expect("artifact hashes match manifest");
    for model in &m.models {
        assert!(model.test_acc > 0.5, "{} acc {}", model.name, model.test_acc);
        assert!(model.param_count > 1_000);
    }
}

#[test]
fn executor_runs_every_model_and_bucket() {
    let m = manifest();
    let exec = Executor::spawn(
        Arc::clone(&m),
        ExecutorOptions {
            verify_sha: true,
            ..Default::default()
        },
    )
    .expect("executor spawns");
    let h = exec.handle();
    for model in &m.models {
        for art in &model.buckets {
            let b = art.bucket;
            let resp = h
                .infer(ExecRequest {
                    model: model.name.clone(),
                    batch: b,
                    data: noise_batch(&m, b, 42 + b as u64).into(),
                })
                .unwrap_or_else(|e| panic!("{} b{b}: {e}", model.name));
            assert_eq!(resp.logits.len(), b * m.num_classes());
            assert_eq!(resp.bucket, b);
            assert!(!resp.backend.is_empty());
            assert!(resp.logits.iter().all(|v| v.is_finite()));
        }
    }
}

#[test]
fn padding_does_not_change_results() {
    // Same rows, served at batch 3 (runs on bucket 4) vs batch 4 exact:
    // the padded execution must return identical logits for shared rows.
    let m = manifest();
    let exec = Executor::spawn(Arc::clone(&m), ExecutorOptions::default()).unwrap();
    let h = exec.handle();
    let elems = m.sample_elems();
    let data4 = noise_batch(&m, 4, 7);
    let data3 = data4[..3 * elems].to_vec();

    for model in m.model_names() {
        let r4 = h
            .infer(ExecRequest {
                model: model.clone(),
                batch: 4,
                data: data4.clone().into(),
            })
            .unwrap();
        let r3 = h
            .infer(ExecRequest {
                model: model.clone(),
                batch: 3,
                data: data3.clone().into(),
            })
            .unwrap();
        assert_eq!(r3.bucket, 4, "batch 3 should round up to bucket 4");
        assert_eq!(r3.logits.len(), 3 * m.num_classes());
        for (i, (a, b)) in r3.logits.iter().zip(r4.logits.iter()).enumerate() {
            assert!(
                (a - b).abs() < 1e-4,
                "{model} row elem {i}: padded {a} vs exact {b}"
            );
        }
    }
}

#[test]
fn deterministic_across_calls() {
    let m = manifest();
    let exec = Executor::spawn(Arc::clone(&m), ExecutorOptions::default()).unwrap();
    let h = exec.handle();
    let data = noise_batch(&m, 2, 99);
    let req = ExecRequest {
        model: "cnn_s".into(),
        batch: 2,
        data: data.into(),
    };
    let a = h.infer(req.clone()).unwrap();
    let b = h.infer(req).unwrap();
    assert_eq!(a.logits, b.logits);
}

#[test]
fn models_disagree_on_inputs() {
    // §2.1 premise: different architectures → different functions.
    let m = manifest();
    let exec = Executor::spawn(Arc::clone(&m), ExecutorOptions::default()).unwrap();
    let h = exec.handle();
    let data = noise_batch(&m, 8, 5);
    let mut all_logits = Vec::new();
    for model in m.model_names() {
        let r = h
            .infer(ExecRequest {
                model,
                batch: 8,
                data: data.clone().into(),
            })
            .unwrap();
        all_logits.push(r.logits);
    }
    assert_ne!(all_logits[0], all_logits[1]);
    assert_ne!(all_logits[1], all_logits[2]);
}

#[test]
fn classifies_synthetic_shapes_correctly() {
    // The end-to-end numerics check that matters: frames generated the same
    // way as python/compile/data.py must be classified sensibly. We draw a
    // crisp cross and a crisp disc with low noise; a >50%-accurate model
    // must distinguish them from blanks on average logits. Trained weights
    // only — the synthetic fallback is random and classifies nothing.
    require_artifacts!();
    let m = Arc::new(Manifest::load(artifact_dir()).expect("manifest loads"));
    let exec = Executor::spawn(Arc::clone(&m), ExecutorOptions::default()).unwrap();
    let h = exec.handle();
    let img = 16usize;
    let norm = flexserve::imagepipe::Normalizer::new(m.norm_mean, m.norm_std);

    // Build: row 0 = blank, row 1 = bold cross (class 2), row 2 = disc (3).
    let mut frames = vec![0.0f32; 3 * img * img];
    for d in 0..img {
        frames[img * img + 8 * img + d] = 1.0; // horizontal bar
        frames[img * img + d * img + 8] = 1.0; // vertical bar
    }
    for y in 0..img {
        for x in 0..img {
            let (dy, dx) = (y as i32 - 8, x as i32 - 8);
            if dy * dy + dx * dx <= 16 {
                frames[2 * img * img + y * img + x] = 1.0;
            }
        }
    }
    norm.apply(&mut frames);

    // cnn_m is the strongest model (~0.89 test acc).
    let r = h
        .infer(ExecRequest {
            model: "cnn_m".into(),
            batch: 3,
            data: frames.into(),
        })
        .unwrap();
    let preds = argmax_rows(&r.logits, m.num_classes());
    assert_eq!(preds[0].0, 0, "blank frame should be class 0, logits {:?}", &r.logits[0..4]);
    assert_eq!(preds[1].0, 2, "cross frame should be class 2, logits {:?}", &r.logits[4..8]);
    assert_eq!(preds[2].0, 3, "disc frame should be class 3, logits {:?}", &r.logits[8..12]);
}

#[test]
fn subset_loading_and_errors() {
    let m = manifest();
    let exec = Executor::spawn(
        Arc::clone(&m),
        ExecutorOptions {
            models: Some(vec!["mlp".into()]),
            buckets: Some(vec![1, 8]),
            ..Default::default()
        },
    )
    .unwrap();
    let h = exec.handle();
    // Loaded model works, batch 2 rounds up to loaded bucket 8.
    let r = h
        .infer(ExecRequest {
            model: "mlp".into(),
            batch: 2,
            data: noise_batch(&m, 2, 1).into(),
        })
        .unwrap();
    assert_eq!(r.bucket, 8);
    // Unloaded model errors cleanly.
    assert!(h
        .infer(ExecRequest {
            model: "cnn_s".into(),
            batch: 1,
            data: noise_batch(&m, 1, 1).into(),
        })
        .is_err());
    // Oversized batch errors cleanly.
    assert!(h
        .infer(ExecRequest {
            model: "mlp".into(),
            batch: 9,
            data: noise_batch(&m, 9, 1).into(),
        })
        .is_err());
    // Wrong payload size errors cleanly.
    assert!(h
        .infer(ExecRequest {
            model: "mlp".into(),
            batch: 2,
            data: vec![0.0f32; 7].into(),
        })
        .is_err());
}

#[test]
fn runtime_load_unload_roundtrip() {
    // The executor-level model lifecycle behind the /v1 control plane:
    // compile a model into a live device, serve it, evict it.
    let m = manifest();
    let exec = Executor::spawn(
        Arc::clone(&m),
        ExecutorOptions {
            models: Some(vec!["mlp".into()]),
            ..Default::default()
        },
    )
    .unwrap();
    let h = exec.handle();
    let probe = || ExecRequest {
        model: "cnn_s".into(),
        batch: 1,
        data: noise_batch(&m, 1, 2).into(),
    };
    // Not resident at boot.
    assert!(h.infer(probe()).is_err());
    // Load compiles it in; a second load is an idempotent no-op.
    assert!(h.load_model("cnn_s").unwrap(), "first load compiles");
    assert!(!h.load_model("cnn_s").unwrap(), "second load is a no-op");
    let r = h.infer(probe()).unwrap();
    assert_eq!(r.logits.len(), m.num_classes());
    // Unload evicts; inference errors again; double-unload reports false.
    assert!(h.unload_model("cnn_s").unwrap());
    assert!(!h.unload_model("cnn_s").unwrap());
    assert!(h.infer(probe()).is_err());
    // Unknown models are rejected.
    assert!(h.load_model("resnet152").is_err());
}

#[test]
fn pool_parallel_load_broadcast_and_least_loaded_dispatch() {
    // Pool-level lifecycle: a runtime load broadcasts to BOTH workers
    // concurrently (one compile of wall-clock, not W) and the pool stays
    // uniform; dispatch accounting tracks in-flight rows per worker.
    let m = manifest();
    let pool = ExecutorPool::spawn(
        Arc::clone(&m),
        ExecutorOptions {
            models: Some(vec!["mlp".into()]),
            ..Default::default()
        },
        2,
    )
    .unwrap();
    assert_eq!(pool.workers(), 2);
    assert!(!pool.is_loaded("cnn_s"));

    // Concurrent broadcast lands on every worker.
    assert!(pool.load_model("cnn_s").unwrap(), "first load compiles");
    assert!(!pool.load_model("cnn_s").unwrap(), "second load is a no-op");
    assert!(pool.is_loaded("cnn_s"));
    for h in pool.handles() {
        let r = h
            .infer(ExecRequest {
                model: "cnn_s".into(),
                batch: 1,
                data: noise_batch(&m, 1, 3).into(),
            })
            .expect("loaded on this worker");
        assert_eq!(r.logits.len(), m.num_classes());
    }
    // Unknown models fail without touching residency.
    assert!(pool.load_model("resnet152").is_err());

    // In-flight accounting: idle pool reads zero everywhere, and every
    // submit-side increment pairs with the device thread's decrement once
    // the jobs drain (the steering rule itself is pinned device-free by
    // `pick_least_loaded`'s unit tests).
    assert_eq!(pool.in_flight_rows(), vec![0, 0]);
    let rxs: Vec<_> = (0..6)
        .map(|i| {
            pool.least_loaded()
                .infer_async(ExecRequest {
                    model: "mlp".into(),
                    batch: 4,
                    data: noise_batch(&m, 4, 40 + i).into(),
                })
                .unwrap()
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    assert_eq!(pool.in_flight_rows(), vec![0, 0], "accounting pairs up");

    // Unload evicts from every worker.
    assert!(pool.unload_model("cnn_s").unwrap());
    assert!(!pool.is_loaded("cnn_s"));
    for h in pool.handles() {
        assert!(h
            .infer(ExecRequest {
                model: "cnn_s".into(),
                batch: 1,
                data: noise_batch(&m, 1, 5).into(),
            })
            .is_err());
    }
}
