//! Cross-backend differential tests.
//!
//! Always-on half (needs no compiled XLA artifacts): every synthetic-zoo
//! model, every manifest bucket, seeded inputs —
//!
//! - `CpuBackend` must match the scalar `ModelGraph::forward_reference`
//!   ground truth within 1e-4 per logit;
//! - `QuantBackend` must agree with the f32 path on argmax for ≥ 90% of
//!   rows (quantization shifts logits, not usually the winner).
//!
//! Artifact-gated half: when real compiled artifacts exist AND an entry
//! carries the layer grammar + weights sidecar, the CPU path must match
//! the XLA executable's output within 1e-4 — same weights, two
//! independent lowering pipelines. Skips silently when artifacts are
//! absent so CI stays device-free.

use flexserve::runtime::backend::{CpuBackend, CpuWorkers, QuantBackend, QuantModel};
use flexserve::runtime::{BufferArena, Manifest, ModelGraph};
use flexserve::runtime::{backend::XlaBackend, synth};
use flexserve::util::Prng;
use std::sync::Arc;

/// Seeded feed for one (model, bucket) pair — deterministic across runs
/// and across the two backends being diffed.
fn seeded_feed(prng: &mut Prng, rows: usize, elems: usize) -> Vec<f32> {
    (0..rows * elems).map(|_| prng.normal() as f32).collect()
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

#[test]
fn cpu_matches_reference_across_zoo_and_buckets() {
    let dir = synth::ensure_synthetic();
    let m = Manifest::load(&dir).expect("synthetic manifest loads");
    let workers = Arc::new(CpuWorkers::new(2));
    let mut arena = BufferArena::new(0);
    let elems = m.sample_elems();
    let mut checked = 0usize;
    for entry in &m.models {
        let graph = Arc::new(ModelGraph::load(&m, entry, true).expect("graph loads"));
        let mut prng = Prng::new(0xD1FF + entry.name.len() as u64);
        for art in &entry.buckets {
            let rows = art.bucket;
            let feed = seeded_feed(&mut prng, rows, elems);
            let want = graph.forward_reference(&feed, rows);
            let mut be = CpuBackend::new(Arc::clone(&graph), rows, Arc::clone(&workers));
            let got = be.run(&feed, &mut arena).expect("cpu run");
            assert_eq!(got.len(), want.len(), "{} b{rows}", entry.name);
            for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{} b{rows} logit {i}: cpu {a} vs reference {b}",
                    entry.name
                );
            }
            checked += 1;
        }
    }
    assert!(checked >= 12, "zoo x buckets should yield many slots, got {checked}");
}

#[test]
fn quant_argmax_agrees_with_f32_across_zoo_and_buckets() {
    let dir = synth::ensure_synthetic();
    let m = Manifest::load(&dir).expect("synthetic manifest loads");
    let mut arena = BufferArena::new(0);
    let elems = m.sample_elems();
    let mut agree = 0usize;
    let mut total = 0usize;
    for entry in &m.models {
        let graph = Arc::new(ModelGraph::load(&m, entry, true).expect("graph loads"));
        let qm = Arc::new(QuantModel::from_graph(&graph));
        let mut prng = Prng::new(0x9_0A17 + entry.name.len() as u64);
        for art in &entry.buckets {
            let rows = art.bucket;
            let feed = seeded_feed(&mut prng, rows, elems);
            let want = graph.forward_reference(&feed, rows);
            let mut be = QuantBackend::new(Arc::clone(&qm), rows);
            let got = be.run(&feed, &mut arena).expect("quant run");
            let classes = graph.out_dim;
            for r in 0..rows {
                total += 1;
                if argmax(&want[r * classes..(r + 1) * classes])
                    == argmax(&got[r * classes..(r + 1) * classes])
                {
                    agree += 1;
                }
            }
        }
    }
    // 3 models x buckets [1,2,4,8,16,32] = 189 rows; u8 quantization must
    // keep at least 90% of argmax decisions.
    assert!(total >= 100, "expected a large row population, got {total}");
    let pct = agree * 100 / total;
    assert!(pct >= 90, "quant argmax agreement {agree}/{total} ({pct}%) < 90%");
}

#[test]
fn quant_run_is_deterministic() {
    let dir = synth::ensure_synthetic();
    let m = Manifest::load(&dir).expect("synthetic manifest loads");
    let entry = &m.models[0];
    let graph = Arc::new(ModelGraph::load(&m, entry, true).unwrap());
    let qm = Arc::new(QuantModel::from_graph(&graph));
    let mut arena = BufferArena::new(0);
    let mut prng = Prng::new(42);
    let feed = seeded_feed(&mut prng, 4, m.sample_elems());
    let mut be = QuantBackend::new(qm, 4);
    let first = be.run(&feed, &mut arena).unwrap().to_vec();
    let second = be.run(&feed, &mut arena).unwrap().to_vec();
    assert_eq!(first, second);
}

/// CPU ≡ XLA on real artifacts: both paths consume the same checkpoint
/// (HLO for the device, the f32 sidecar for the CPU grammar), so their
/// logits must agree to float tolerance. Requires `make artifacts` output
/// whose manifest entries carry `layers`; skips otherwise.
#[test]
fn cpu_matches_xla_on_real_artifacts() {
    let dir = std::env::var_os("FLEXSERVE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping cpu_matches_xla_on_real_artifacts: no artifacts at {dir:?}");
        return;
    }
    let m = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping cpu_matches_xla_on_real_artifacts: manifest unreadable: {e:#}");
            return;
        }
    };
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping cpu_matches_xla_on_real_artifacts: no PJRT client: {e:?}");
            return;
        }
    };
    let workers = Arc::new(CpuWorkers::new(2));
    let mut arena = BufferArena::new(0);
    let elems = m.sample_elems();
    let mut diffed = 0usize;
    for entry in &m.models {
        if entry.layers.is_empty() || entry.weights.is_none() {
            continue; // XLA-only checkpoint: nothing to diff against.
        }
        let graph = Arc::new(ModelGraph::load(&m, entry, true).expect("sidecar graph loads"));
        let mut prng = Prng::new(0xA2E4 + entry.name.len() as u64);
        for art in &entry.buckets {
            let rows = art.bucket;
            let path = m.artifact_path(art);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .unwrap_or_else(|e| panic!("parsing HLO {path:?}: {e:?}"));
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .unwrap_or_else(|e| panic!("compiling {}: {e:?}", art.file));
            let mut dev = XlaBackend::new(exe, rows, &m.input_shape);
            let mut cpu = CpuBackend::new(Arc::clone(&graph), rows, Arc::clone(&workers));
            let feed = seeded_feed(&mut prng, rows, elems);
            let want = dev.run(&feed, &mut arena).expect("xla run");
            let got = cpu.run(&feed, &mut arena).expect("cpu run");
            assert_eq!(got.len(), want.len(), "{} b{rows}", entry.name);
            for i in 0..got.len() {
                assert!(
                    (got[i] - want[i]).abs() < 1e-4,
                    "{} b{rows} logit {i}: cpu {} vs xla {}",
                    entry.name,
                    got[i],
                    want[i]
                );
            }
            diffed += 1;
        }
    }
    if diffed == 0 {
        eprintln!("cpu_matches_xla_on_real_artifacts: no entries carry layers — nothing diffed");
    } else {
        eprintln!("cpu_matches_xla_on_real_artifacts: {diffed} (model x bucket) slots agree");
    }
}
