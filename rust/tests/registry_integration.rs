//! Registry integration: the full versioned-rollout lifecycle on a live
//! server (always-on: real artifacts when present, else the synthetic
//! CPU-backend set). Builds a temp *versioned* artifact
//! layout out of the flat one (`<model>/2/` with its own manifest and a
//! distinct `params_sha256`), then drives: load v2 alongside v1 → 10%
//! canary with a deterministic per-request-id hash split → injected
//! failures tripping auto-rollback → promote → v1 unloads cleanly while
//! v2 keeps serving — with every transition (and both versions' shas) on
//! the audit trail, and the flat-layout wire format intact throughout.
//!
//! Tests share one server and serialize on GUARD (rollout state is
//! per-model global).

use flexserve::config::ServeConfig;
use flexserve::coordinator::{serve, SchedConfig, ServerState};
use flexserve::http::{Client, Request, ServerHandle};
use flexserve::json::{self, Value};
use flexserve::registry::canary_pick;
use flexserve::runtime::Manifest;
use flexserve::util::Prng;
use flexserve::workload;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Real artifacts when `make artifacts` produced them, else the seeded
/// synthetic CPU-backend set — this suite is always-on either way.
fn artifact_dir() -> PathBuf {
    flexserve::runtime::synth::ensure_artifacts()
}

/// The versioned temp layout: a copy of the flat artifacts plus
/// `mlp/2/` and `cnn_s/2/` version directories re-using the real HLO
/// bytes under fresh `params_sha256` tags.
fn versioned_layout() -> PathBuf {
    let src = artifact_dir();
    let dst = std::env::temp_dir().join("flexserve_registry_itest");
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(&dst).unwrap();
    for entry in std::fs::read_dir(&src).unwrap() {
        let entry = entry.unwrap();
        if entry.path().is_file() {
            std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
        }
    }
    let base = Manifest::load(&dst).unwrap();
    write_version(&base, &dst, "mlp", 2, "v2-mlp-params-sha");
    write_version(&base, &dst, "cnn_s", 2, "v2-cnn-s-params-sha");
    dst
}

/// Write `<dst>/<model>/<version>/` with copies of the model's artifacts
/// and a per-version manifest carrying `params_sha` as its provenance.
fn write_version(base: &Manifest, dst: &Path, model: &str, version: u32, params_sha: &str) {
    let entry = base.model(model).unwrap();
    let vdir = dst.join(model).join(version.to_string());
    std::fs::create_dir_all(&vdir).unwrap();
    let mut buckets: Vec<(String, Value)> = Vec::new();
    for a in &entry.buckets {
        std::fs::copy(base.dir.join(&a.file), vdir.join(&a.file)).unwrap();
        buckets.push((
            a.bucket.to_string(),
            json::obj([
                ("file", Value::from(a.file.as_str())),
                ("sha256", Value::from(a.sha256.as_str())),
                ("bytes", Value::from(a.bytes)),
            ]),
        ));
    }
    // Propagate the execution-backend grammar when the base entry carries
    // it (synthetic CPU-backend artifacts): the version store loads each
    // version from its own manifest, so backend/layers/weights must
    // travel with it just like the buckets do.
    let mut model_doc = vec![
        ("param_count".to_string(), Value::from(entry.param_count)),
        ("test_acc".to_string(), Value::from(entry.test_acc)),
        ("params_sha256".to_string(), Value::from(params_sha)),
    ];
    if let Some(backend) = &entry.backend {
        model_doc.push(("backend".to_string(), Value::from(backend.as_str())));
    }
    if !entry.layers.is_empty() {
        let layers: Vec<Value> = entry
            .layers
            .iter()
            .map(|l| {
                json::obj([
                    ("op", Value::from(l.op.as_str())),
                    ("in", Value::from(l.in_dim)),
                    ("out", Value::from(l.out_dim)),
                    ("act", Value::from(l.act.as_str())),
                    ("w_off", Value::from(l.w_off)),
                    ("b_off", Value::from(l.b_off)),
                ])
            })
            .collect();
        model_doc.push(("layers".to_string(), Value::Arr(layers)));
    }
    if let Some(w) = &entry.weights {
        std::fs::copy(base.dir.join(&w.file), vdir.join(&w.file)).unwrap();
        model_doc.push((
            "weights".to_string(),
            json::obj([
                ("file", Value::from(w.file.as_str())),
                ("sha256", Value::from(w.sha256.as_str())),
                ("bytes", Value::from(w.bytes)),
            ]),
        ));
    }
    model_doc.push(("buckets".to_string(), Value::Obj(buckets)));
    let doc = json::obj([
        ("format_version", Value::from(1u64)),
        (
            "input_shape",
            Value::Arr(base.input_shape.iter().map(|&d| Value::from(d)).collect()),
        ),
        (
            "classes",
            Value::Arr(base.classes.iter().map(|c| Value::from(c.as_str())).collect()),
        ),
        (
            "normalize",
            json::obj([
                ("mean", Value::from(base.norm_mean as f64)),
                ("std", Value::from(base.norm_std as f64)),
            ]),
        ),
        (
            "buckets",
            Value::Arr(base.buckets.iter().map(|&b| Value::from(b)).collect()),
        ),
        (
            "models",
            Value::Obj(vec![(model.to_string(), Value::Obj(model_doc))]),
        ),
    ]);
    std::fs::write(vdir.join("manifest.json"), json::to_string_pretty(&doc)).unwrap();
}

struct Stack {
    handle: ServerHandle,
    state: Arc<ServerState>,
    audit_path: PathBuf,
}

static STACK: OnceLock<Stack> = OnceLock::new();
static GUARD: Mutex<()> = Mutex::new(());

fn stack() -> &'static Stack {
    STACK.get_or_init(|| {
        let dir = versioned_layout();
        let audit_path = dir.join("audit.jsonl");
        let mut config = ServeConfig::default();
        config.addr = "127.0.0.1:0".into();
        config.artifacts = dir;
        config.http_workers = 4;
        config.device_workers = 1;
        config.warmup = false;
        config.scheduler = Some(SchedConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(1),
            adaptive: false,
            ..Default::default()
        });
        config.registry.audit_log = Some(audit_path.clone());
        config.registry.guardrails.min_samples = 10;
        let (handle, state) = serve(&config).expect("registry server starts");
        Stack { handle, state, audit_path }
    })
}

fn client() -> Client {
    Client::connect(stack().handle.addr).unwrap()
}

fn predict_body(batch: usize, seed: u64) -> Value {
    let mut rng = Prng::new(seed);
    let (data, _) = workload::make_batch(&mut rng, batch);
    json::obj([
        (
            "data",
            Value::Arr(data.iter().map(|&v| Value::from(v)).collect()),
        ),
        ("batch", Value::from(batch)),
    ])
}

/// Single-model predict with detail + an explicit request id; returns
/// `(status, detail.version, params_sha256)`.
fn predict_mlp(c: &mut Client, rid: &str, version: Option<u32>) -> (u16, u64, String) {
    let mut body = predict_body(1, 7);
    if let Value::Obj(m) = &mut body {
        m.push(("detail".into(), Value::Bool(true)));
        if let Some(v) = version {
            m.push(("version".into(), Value::from(v as u64)));
        }
    }
    let mut req = Request::new(
        "POST",
        "/v1/models/mlp/predict",
        json::to_string(&body).into_bytes(),
    );
    req.headers.push(("content-type".into(), "application/json".into()));
    req.headers.push(("x-request-id".into(), rid.into()));
    let resp = c.request(&req).unwrap();
    let doc = resp.json_body().unwrap_or(Value::Null);
    let served = doc.path(&["detail", "version"]).and_then(Value::as_u64).unwrap_or(0);
    let sha = doc
        .get("params_sha256")
        .and_then(Value::as_str)
        .unwrap_or("")
        .to_string();
    (resp.status, served, sha)
}

fn error_code(r: &flexserve::http::Response) -> String {
    r.json_body()
        .unwrap()
        .path(&["error", "code"])
        .and_then(Value::as_str)
        .unwrap_or("<none>")
        .to_string()
}

fn audit_events(c: &mut Client) -> Vec<(String, String)> {
    c.audit(100)
        .unwrap()
        .get("audit")
        .and_then(Value::as_arr)
        .map(|a| {
            a.iter()
                .map(|e| {
                    (
                        e.get("event").and_then(Value::as_str).unwrap_or("").to_string(),
                        e.get("actor").and_then(Value::as_str).unwrap_or("").to_string(),
                    )
                })
                .collect()
        })
        .unwrap_or_default()
}

#[test]
fn full_rollout_lifecycle_canary_autorollback_promote() {
    let _g = GUARD.lock().unwrap();
    let st = stack();
    let mut c = client();

    // ---- the versioned catalog is visible; v1 serves byte-compatibly ----
    let models = c.models().unwrap();
    let mlp = models
        .get("models")
        .and_then(Value::as_arr)
        .unwrap()
        .iter()
        .find(|m| m.get("name").and_then(Value::as_str) == Some("mlp"))
        .expect("mlp in the registry table")
        .clone();
    let versions = mlp.get("versions").and_then(Value::as_arr).unwrap();
    assert_eq!(versions.len(), 2, "flat layout = v1, subdir = v2");
    assert_eq!(versions[0].get("status").unwrap().as_str(), Some("active"));
    assert_eq!(versions[1].get("status").unwrap().as_str(), Some("unloaded"));
    assert_eq!(mlp.get("version").unwrap().as_u64(), Some(1));
    assert_eq!(
        versions[1].get("params_sha256").unwrap().as_str(),
        Some("v2-mlp-params-sha")
    );
    let v1_sha = versions[0]
        .get("params_sha256")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();

    // Legacy alias and /v1 serve identical bytes, version members absent
    // (the flat wire contract survives the registry).
    let body = predict_body(2, 3);
    let legacy = c.post_json("/predict", &body).unwrap();
    let v1 = c.post_json("/v1/predict", &body).unwrap();
    assert_eq!(legacy.status, 200, "{}", String::from_utf8_lossy(&legacy.body));
    assert_eq!(legacy.body, v1.body, "legacy alias must stay byte-compatible");
    let doc = legacy.json_body().unwrap();
    assert!(doc.get("model_mlp").is_some() && doc.get("model_mlp@2").is_none());

    // ---- load v2 alongside v1 ----
    let doc = c.load_model_version("mlp", 2).unwrap();
    assert_eq!(doc.get("status").unwrap().as_str(), Some("loaded"));
    assert_eq!(doc.get("version").unwrap().as_u64(), Some(2));
    assert_eq!(doc.get("params_sha256").unwrap().as_str(), Some("v2-mlp-params-sha"));
    // Both versions resident concurrently.
    assert!(st.state.ensemble.pool().is_version_loaded("mlp", 1));
    assert!(st.state.ensemble.pool().is_version_loaded("mlp", 2));

    // Version slots are not ensemble members (membership is model
    // identity; versions are a rollout concern).
    let r = c
        .put_json(
            "/v1/ensemble",
            &json::obj([(
                "models",
                Value::Arr(vec![Value::from("mlp"), Value::from("mlp@2")]),
            )]),
        )
        .unwrap();
    assert_eq!((r.status, error_code(&r)), (422, "bad_input.bad_value".to_string()));

    // Version-pinned inference on both codecs; unknown pins fail typed.
    let (status, served, sha) = predict_mlp(&mut c, "pin-check", Some(2));
    assert_eq!((status, served), (200, 2));
    assert_eq!(sha, "v2-mlp-params-sha");
    let (status, served, sha) = predict_mlp(&mut c, "pin-check", Some(1));
    assert_eq!((status, served), (200, 1));
    assert_eq!(sha, v1_sha);
    let (status, _, _) = predict_mlp(&mut c, "pin-check", Some(9));
    assert_eq!(status, 404);
    let mut rng = Prng::new(5);
    let (data, _) = workload::make_batch(&mut rng, 1);
    let shape = [1, workload::IMG, workload::IMG, 1];
    let v2_doc = c.v2_infer("mlp", &shape, &data).unwrap();
    assert_eq!(v2_doc.get("model_version").unwrap().as_str(), Some("1"));
    let v2_body = json::parse(&format!(
        r#"{{"inputs":[{{"name":"input","datatype":"FP32","shape":[1,{e}],
            "data":{data}}}],"parameters":{{"version":2}}}}"#,
        e = workload::IMG * workload::IMG,
        data = json::to_string(&Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
    ))
    .unwrap();
    let resp = c.post_json("/v2/models/mlp/infer", &v2_body).unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let doc = resp.json_body().unwrap();
    assert_eq!(doc.get("model_version").unwrap().as_str(), Some("2"));
    assert_eq!(
        doc.path(&["parameters", "params_sha256"]).unwrap().as_str(),
        Some("v2-mlp-params-sha")
    );

    // ---- 10% canary: the hash split is deterministic per request id ----
    c.set_rollout("mlp", "canary", 2, Some(10)).unwrap();
    let roll = c.get_rollout("mlp").unwrap();
    assert_eq!(roll.get("mode").unwrap().as_str(), Some("canary"));
    assert_eq!(roll.get("percent").unwrap().as_u64(), Some(10));
    let (mut stable_seen, mut canary_seen) = (0u32, 0u32);
    let mut i = 0;
    while (stable_seen < 3 || canary_seen < 3) && i < 500 {
        let rid = format!("canary-rid-{i}");
        i += 1;
        let (status, served, _) = predict_mlp(&mut c, &rid, None);
        assert_eq!(status, 200);
        let expect = if canary_pick(&rid, 10) { 2 } else { 1 };
        assert_eq!(served, expect, "{rid}: split must follow the pure hash rule");
        // Same id re-sent lands on the same version.
        let (_, again, _) = predict_mlp(&mut c, &rid, None);
        assert_eq!(again, served, "{rid}: split must be deterministic");
        if served == 2 { canary_seen += 1 } else { stable_seen += 1 }
    }
    assert!(stable_seen >= 3 && canary_seen >= 3, "degenerate split after {i} ids");

    // ---- injected failures trip auto-rollback ----
    // Restart the canary so the candidate window is clean, then feed it
    // failing outcomes (the guardrail input) until the error rate rail
    // (>0.5 over ≥10 samples) fires.
    c.set_rollout("mlp", "canary", 2, Some(10)).unwrap();
    for _ in 0..12 {
        st.state.registry.record_outcome("mlp", 2, false, 2_000);
    }
    let roll = c.get_rollout("mlp").unwrap();
    assert_eq!(roll.get("mode").unwrap().as_str(), Some("pin"), "{roll}");
    assert_eq!(roll.get("active_version").unwrap().as_u64(), Some(1));
    // All traffic back on v1, including previously-canaried ids.
    let rid_on_candidate = (0..500)
        .map(|i| format!("canary-rid-{i}"))
        .find(|rid| canary_pick(rid, 10))
        .unwrap();
    let (_, served, _) = predict_mlp(&mut c, &rid_on_candidate, None);
    assert_eq!(served, 1, "rollback must stop the canary split");

    // ---- promote, then v1 unloads cleanly while v2 keeps serving ----
    c.set_rollout("mlp", "canary", 2, Some(10)).unwrap();
    let doc = c.promote("mlp").unwrap();
    assert_eq!(doc.get("active_version").unwrap().as_u64(), Some(2));
    let (_, served, sha) = predict_mlp(&mut c, "post-promote", None);
    assert_eq!((served, sha.as_str()), (2, "v2-mlp-params-sha"));

    let doc = c.unload_model_version("mlp", 1).unwrap();
    assert_eq!(doc.get("status").unwrap().as_str(), Some("unloaded"));
    assert!(!st.state.ensemble.pool().is_version_loaded("mlp", 1));
    assert!(st.state.ensemble.pool().is_version_loaded("mlp", 2));
    // v2 still serves the model — single-model, ensemble, and /v2 routes.
    let (status, served, _) = predict_mlp(&mut c, "post-unload", None);
    assert_eq!((status, served), (200, 2));
    let resp = c.post_json("/v1/predict", &predict_body(2, 11)).unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let doc = resp.json_body().unwrap();
    assert_eq!(doc.get("model_mlp").unwrap().as_arr().unwrap().len(), 2);
    assert!(c.v2_ready(Some("mlp")).unwrap(), "v2 still ready via version 2");

    // Mid-rollout unloaded version → typed model.version_unknown on BOTH
    // codecs (not a 500).
    let (status, _, _) = predict_mlp(&mut c, "gone-pin", Some(1));
    assert_eq!(status, 404);
    let mut body = predict_body(1, 13);
    if let Value::Obj(m) = &mut body {
        m.push(("version".into(), Value::from(1u64)));
    }
    let resp = c.post_json("/v1/models/mlp/predict", &body).unwrap();
    assert_eq!((resp.status, error_code(&resp)), (404, "model.version_unknown".to_string()));
    let v2_body = json::parse(&format!(
        r#"{{"inputs":[{{"name":"input","datatype":"FP32","shape":[1,{e}],
            "data":{data}}}],"parameters":{{"version":1}}}}"#,
        e = workload::IMG * workload::IMG,
        data = json::to_string(&Value::Arr(data.iter().map(|&v| Value::from(v)).collect())),
    ))
    .unwrap();
    let resp = c.post_json("/v2/models/mlp/infer", &v2_body).unwrap();
    assert_eq!(resp.status, 404);
    let err = resp
        .json_body()
        .unwrap()
        .get("error")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    assert!(err.starts_with("model.version_unknown:"), "{err}");

    // ---- shadow mode mirrors off the hot path ----
    c.load_model_version("mlp", 1).unwrap();
    let body = json::obj([
        ("mode", Value::from("shadow")),
        ("version", Value::from(1u64)),
    ]);
    let resp = c.put_json("/v1/models/mlp/rollout", &body).unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let before = st.state.metrics.counter("ver_mlp_v1_shadow_requests_total");
    for i in 0..4 {
        let (status, served, _) = predict_mlp(&mut c, &format!("shadow-{i}"), None);
        assert_eq!((status, served), (200, 2), "shadow never changes the response");
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while st.state.metrics.counter("ver_mlp_v1_shadow_requests_total") == before
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(
        st.state.metrics.counter("ver_mlp_v1_shadow_requests_total") > before,
        "shadow mirror never executed"
    );
    c.rollback("mlp").unwrap(); // abandon the shadow, stay pinned at v2

    // ---- the audit trail recorded every transition with both shas ----
    let events = audit_events(&mut c);
    let names: Vec<&str> = events.iter().map(|(e, _)| e.as_str()).collect();
    for expected in ["load", "canary", "rollback", "promote", "unload", "shadow"] {
        assert!(names.contains(&expected), "audit missing '{expected}': {names:?}");
    }
    // The guardrail rollback is attributed to the guardrail, not a human.
    assert!(
        events.iter().any(|(e, a)| e == "rollback" && a == "guardrail"),
        "{events:?}"
    );
    // The durable JSONL trail carries the same records with both shas.
    let text = std::fs::read_to_string(&st.audit_path).unwrap();
    let promote_line = text
        .lines()
        .find(|l| l.contains(r#""event":"promote""#))
        .expect("promote in the audit file");
    assert!(promote_line.contains(&v1_sha), "{promote_line}");
    assert!(promote_line.contains("v2-mlp-params-sha"), "{promote_line}");
    for line in text.lines() {
        let v = json::parse(line).expect("every audit line is one JSON object");
        assert!(v.get("ts_ms").is_some() && v.get("actor").is_some());
    }

    // ---- per-version series in the metrics expositions ----
    let resp = c.get("/v1/metrics?format=prometheus").unwrap();
    let prom = String::from_utf8(resp.body).unwrap();
    assert!(prom.contains("flexserve_ver_mlp_v1_requests_total"), "{prom}");
    assert!(prom.contains("flexserve_ver_mlp_v2_requests_total"), "{prom}");

    // Leave the model pinned at v2 with both versions loaded; the other
    // test uses cnn_s only.
}

#[test]
fn corrupted_version_load_is_typed_provenance_error() {
    let _g = GUARD.lock().unwrap();
    let st = stack();
    let mut c = client();

    // Tamper with cnn_s v2 AFTER boot verification passed.
    let victim = st
        .state
        .manifest
        .dir
        .join("cnn_s")
        .join("2")
        .join(
            st.state
                .registry
                .store()
                .entry("cnn_s", 2)
                .unwrap()
                .buckets[0]
                .file
                .rsplit('/')
                .next()
                .unwrap(),
        );
    // Byte append, not text: the artifact may be a binary weights sidecar.
    let mut bytes = std::fs::read(&victim).unwrap();
    bytes.extend_from_slice(b"\n// tampered");
    std::fs::write(&victim, bytes).unwrap();

    let resp = c.post("/v1/models/cnn_s/load?version=2", Vec::new()).unwrap();
    assert_eq!(
        (resp.status, error_code(&resp)),
        (409, "model.provenance".to_string()),
        "{}",
        String::from_utf8_lossy(&resp.body)
    );
    // The rejected version never became loadable or servable.
    assert!(!st.state.ensemble.pool().is_version_loaded("cnn_s", 2));
    let mut body = predict_body(1, 17);
    if let Value::Obj(m) = &mut body {
        m.push(("version".into(), Value::from(2u64)));
    }
    let resp = c.post_json("/v1/models/cnn_s/predict", &body).unwrap();
    assert_eq!((resp.status, error_code(&resp)), (404, "model.version_unknown".to_string()));
    // v1 keeps serving untouched.
    let resp = c.post_json("/v1/models/cnn_s/predict", &predict_body(1, 19)).unwrap();
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));

    // Unknown-version lifecycle requests are typed too.
    let resp = c.post("/v1/models/cnn_s/load?version=7", Vec::new()).unwrap();
    assert_eq!((resp.status, error_code(&resp)), (404, "model.version_unknown".to_string()));
    let resp = c.post("/v1/models/cnn_s/unload?version=7", Vec::new()).unwrap();
    assert_eq!((resp.status, error_code(&resp)), (404, "model.version_unknown".to_string()));
}
