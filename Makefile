# FlexServe-RS build orchestration.
#
#   make artifacts   train the model zoo and AOT-lower it to HLO artifacts
#                    (rust/artifacts/manifest.json + *.hlo.txt) — the input
#                    the Rust server compiles at boot
#   make serve       release-build and start the ensemble server
#   make test        tier-1 verify: release build + tests
#   make bench       build the bench harness and smoke it against an
#                    in-process echo target (no artifacts needed); point
#                    it at a live server with BENCH_FLAGS='--addr ...'.
#                    Runs once per wire (v1 HTTP, framed mux) and writes
#                    both records into BENCH_serve.json
#   make gateway-smoke  device-free gateway cycle: stickiness, kill,
#                    ejection, rerouting over in-process echo replicas
#   make chaos-smoke device-free failure-containment cycle under a seeded
#                    chaos plane: injected panics + connection drops,
#                    breaker trip/recover, supervisor respawns
#   make mux-smoke   device-free streaming cycle: 100 out-of-order
#                    correlations on one framed /v1/mux connection, a live
#                    subscription observing an injected rollout, the
#                    plain-HTTP /v1/events stream
#   make backend-smoke  device-free full-stack boot on the pure-Rust
#                    backends (cpu, then quant) over synthetic artifacts:
#                    v1 + v2 + mux wires, per-backend metrics, a live
#                    unload/load cycle — no XLA artifacts required
#   make tenant-smoke  device-free multi-tenant cycle: keyed auth
#                    (401/403), token-bucket sheds with Retry-After, a
#                    weighted-fair goodput split, per-tenant Prometheus
#                    series, and a PUT /v1/tenants hot reload
#   make bench-compare  regression gate: stash the committed
#                    BENCH_serve.json, regenerate it via `make bench`, and
#                    fail when p99 or throughput drifts past the tolerance
#                    (default 15%; BENCH_TOLERANCE=N overrides)
#   make check-docs  fail if the /v2 routes in rust/src/coordinator/v2.rs,
#                    the streaming plane (/v1/mux, /v1/events, mux.*
#                    error codes), the execution-backend surface
#                    (--backend flags, model.backend_unsupported), or the
#                    multi-tenant surface (auth/tenant taxonomy codes,
#                    /v1/tenants, --tenants-file) drift from the README
#
# `artifacts` needs the python side (jax + the pallas kernels); the Rust
# targets need only cargo. Device-backed Rust tests self-skip when
# artifacts are missing.

PYTHON ?= python3
ARTIFACTS ?= rust/artifacts

BENCH_FLAGS ?= --echo --connections 4 --duration-secs 3
# The in-process cpu/quant serve stacks do real inference per request, so
# the baseline run is kept short; they exist to catch relative drift, not
# to saturate the box.
BENCH_STACK_FLAGS ?= --connections 2 --duration-secs 2

.PHONY: artifacts serve test bench bench-compare backend-smoke gateway-smoke chaos-smoke mux-smoke tenant-smoke check-docs fmt clippy

artifacts:
	cd python/compile && $(PYTHON) aot.py --out ../../$(ARTIFACTS)

serve:
	cd rust && cargo run --release -- serve

test:
	cd rust && cargo build --release && cargo test -q

# One record per wire and per available backend, one file: the v1 and mux
# echo baselines (`--protocol mux` appended last wins over any protocol in
# BENCH_FLAGS), plus one record each against an in-process serve stack
# pinned to the cpu and quant backends over synthetic artifacts. The
# wrapper is plain JSON so the CI artifact diffs against the committed
# numbers per (wire, backend) key — see `make bench-compare`.
bench:
	cd rust && cargo run --release -- bench $(BENCH_FLAGS) --out /tmp/flexserve_bench_v1.json
	cd rust && cargo run --release -- bench $(BENCH_FLAGS) --protocol mux --out /tmp/flexserve_bench_mux.json
	cd rust && cargo run --release -- bench --backend-stack cpu $(BENCH_STACK_FLAGS) --out /tmp/flexserve_bench_cpu.json
	cd rust && cargo run --release -- bench --backend-stack quant $(BENCH_STACK_FLAGS) --out /tmp/flexserve_bench_quant.json
	@{ printf '{\n"bench": "flexserve-serve-baselines",\n"v1": '; \
	   cat /tmp/flexserve_bench_v1.json; \
	   printf ',\n"mux": '; \
	   cat /tmp/flexserve_bench_mux.json; \
	   printf ',\n"cpu": '; \
	   cat /tmp/flexserve_bench_cpu.json; \
	   printf ',\n"quant": '; \
	   cat /tmp/flexserve_bench_quant.json; \
	   printf '}\n'; } > BENCH_serve.json
	@echo "wrote BENCH_serve.json (v1 + mux echo, cpu + quant stack baselines)"

# Gate: the committed BENCH_serve.json is the baseline; a fresh `make
# bench` is the candidate. Keys present on only one side (a backend the
# baseline predates) pass through; shared keys fail the build past the
# tolerance. BENCH_TOLERANCE=25 loosens the gate on noisy boxes.
bench-compare:
	cp BENCH_serve.json /tmp/flexserve_bench_baseline.json
	$(MAKE) bench
	cd rust && cargo run --release -- bench-compare /tmp/flexserve_bench_baseline.json ../BENCH_serve.json

backend-smoke:
	cd rust && cargo run --release -- backend-smoke

gateway-smoke:
	cd rust && cargo run --release -- gateway-smoke

chaos-smoke:
	cd rust && cargo run --release -- chaos-smoke

mux-smoke:
	cd rust && cargo run --release -- mux-smoke

tenant-smoke:
	cd rust && cargo run --release -- tenant-smoke

# Every quoted "/v2..." string in v2.rs is a route pattern (the module
# keeps other /v2 spellings out of string literals); each must appear
# verbatim in the README's Protocols section. The streaming plane's
# routes, topics and error codes must likewise stay documented.
check-docs:
	@ok=1; \
	for r in $$(grep -oE '"/v2[^"]*"' rust/src/coordinator/v2.rs | tr -d '"' | sort -u); do \
		grep -qF -- "$$r" README.md || { echo "check-docs: README.md is missing v2 route $$r"; ok=0; }; \
	done; \
	for s in '/v1/mux' '/v1/events' 'mux.bad_frame' 'mux.duplicate_id' 'gateway.mux_unrouted' \
			'?topics=' '?since=' 'lagged'; do \
		grep -qF -- "$$s" README.md || { echo "check-docs: README.md is missing streaming doc $$s"; ok=0; }; \
	done; \
	for t in $$(grep -oE 'TOPIC_[A-Z]+: &str = "[a-z]+"' rust/src/mux/events.rs | grep -oE '"[a-z]+"' | tr -d '"'); do \
		grep -qE "^\| .$$t." README.md || { echo "check-docs: README.md topic table is missing '$$t'"; ok=0; }; \
	done; \
	for b in 'Execution backends' 'model.backend_unsupported' '--backend' '--backend-override' \
			'--cpu-workers' '--arena-cap-mb' 'bench-compare' 'backend-smoke'; do \
		grep -qF -- "$$b" README.md || { echo "check-docs: README.md is missing backend doc $$b"; ok=0; }; \
	done; \
	for t in 'Multi-tenancy' 'auth.missing_key' 'auth.unknown_key' 'tenant.rate_limited' \
			'tenant.quota_exceeded' 'events.subscriber_limit' '/v1/tenants' '--tenants-file' \
			'--events-max-subscribers' '--tenant-mix' '--api-key' 'tenant-smoke'; do \
		grep -qF -- "$$t" README.md || { echo "check-docs: README.md is missing tenancy doc $$t"; ok=0; }; \
	done; \
	[ $$ok -eq 1 ] && echo "check-docs: README covers every v2 route, the streaming plane, the backend surface, and the tenant plane"

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy -- -D warnings
