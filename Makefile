# FlexServe-RS build orchestration.
#
#   make artifacts   train the model zoo and AOT-lower it to HLO artifacts
#                    (rust/artifacts/manifest.json + *.hlo.txt) — the input
#                    the Rust server compiles at boot
#   make serve       release-build and start the ensemble server
#   make test        tier-1 verify: release build + tests
#   make bench       build the bench harness and smoke it against an
#                    in-process echo target (no artifacts needed); point
#                    it at a live server with BENCH_FLAGS='--addr ...'
#
# `artifacts` needs the python side (jax + the pallas kernels); the Rust
# targets need only cargo. Device-backed Rust tests self-skip when
# artifacts are missing.

PYTHON ?= python3
ARTIFACTS ?= rust/artifacts

BENCH_FLAGS ?= --echo --connections 4 --duration-secs 3

.PHONY: artifacts serve test bench fmt clippy

artifacts:
	cd python/compile && $(PYTHON) aot.py --out ../../$(ARTIFACTS)

serve:
	cd rust && cargo run --release -- serve

test:
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && cargo run --release -- bench $(BENCH_FLAGS) --out ../BENCH_serve.json
	@echo "wrote BENCH_serve.json"

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy -- -D warnings
