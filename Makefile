# FlexServe-RS build orchestration.
#
#   make artifacts   train the model zoo and AOT-lower it to HLO artifacts
#                    (rust/artifacts/manifest.json + *.hlo.txt) — the input
#                    the Rust server compiles at boot
#   make serve       release-build and start the ensemble server
#   make test        tier-1 verify: release build + tests
#
# `artifacts` needs the python side (jax + the pallas kernels); the Rust
# targets need only cargo. Device-backed Rust tests self-skip when
# artifacts are missing.

PYTHON ?= python3
ARTIFACTS ?= rust/artifacts

.PHONY: artifacts serve test fmt clippy

artifacts:
	cd python/compile && $(PYTHON) aot.py --out ../../$(ARTIFACTS)

serve:
	cd rust && cargo run --release -- serve

test:
	cd rust && cargo build --release && cargo test -q

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy -- -D warnings
