# FlexServe-RS build orchestration.
#
#   make artifacts   train the model zoo and AOT-lower it to HLO artifacts
#                    (rust/artifacts/manifest.json + *.hlo.txt) — the input
#                    the Rust server compiles at boot
#   make serve       release-build and start the ensemble server
#   make test        tier-1 verify: release build + tests
#   make bench       build the bench harness and smoke it against an
#                    in-process echo target (no artifacts needed); point
#                    it at a live server with BENCH_FLAGS='--addr ...'.
#                    Runs once per wire (v1 HTTP, framed mux) and writes
#                    both records into BENCH_serve.json
#   make gateway-smoke  device-free gateway cycle: stickiness, kill,
#                    ejection, rerouting over in-process echo replicas
#   make chaos-smoke device-free failure-containment cycle under a seeded
#                    chaos plane: injected panics + connection drops,
#                    breaker trip/recover, supervisor respawns
#   make mux-smoke   device-free streaming cycle: 100 out-of-order
#                    correlations on one framed /v1/mux connection, a live
#                    subscription observing an injected rollout, the
#                    plain-HTTP /v1/events stream
#   make check-docs  fail if the /v2 routes in rust/src/coordinator/v2.rs
#                    or the streaming plane (/v1/mux, /v1/events, mux.*
#                    error codes) drift from the README
#
# `artifacts` needs the python side (jax + the pallas kernels); the Rust
# targets need only cargo. Device-backed Rust tests self-skip when
# artifacts are missing.

PYTHON ?= python3
ARTIFACTS ?= rust/artifacts

BENCH_FLAGS ?= --echo --connections 4 --duration-secs 3

.PHONY: artifacts serve test bench gateway-smoke chaos-smoke mux-smoke check-docs fmt clippy

artifacts:
	cd python/compile && $(PYTHON) aot.py --out ../../$(ARTIFACTS)

serve:
	cd rust && cargo run --release -- serve

test:
	cd rust && cargo build --release && cargo test -q

# Two records, one file: the v1 request/response baseline and the mux
# framed-wire baseline (`--protocol mux` appended last wins over any
# protocol in BENCH_FLAGS). The wrapper is plain JSON so the CI artifact
# diffs against the committed numbers per wire.
bench:
	cd rust && cargo run --release -- bench $(BENCH_FLAGS) --out /tmp/flexserve_bench_v1.json
	cd rust && cargo run --release -- bench $(BENCH_FLAGS) --protocol mux --out /tmp/flexserve_bench_mux.json
	@{ printf '{\n"bench": "flexserve-serve-baselines",\n"v1": '; \
	   cat /tmp/flexserve_bench_v1.json; \
	   printf ',\n"mux": '; \
	   cat /tmp/flexserve_bench_mux.json; \
	   printf '}\n'; } > BENCH_serve.json
	@echo "wrote BENCH_serve.json (v1 + mux echo baselines)"

gateway-smoke:
	cd rust && cargo run --release -- gateway-smoke

chaos-smoke:
	cd rust && cargo run --release -- chaos-smoke

mux-smoke:
	cd rust && cargo run --release -- mux-smoke

# Every quoted "/v2..." string in v2.rs is a route pattern (the module
# keeps other /v2 spellings out of string literals); each must appear
# verbatim in the README's Protocols section. The streaming plane's
# routes, topics and error codes must likewise stay documented.
check-docs:
	@ok=1; \
	for r in $$(grep -oE '"/v2[^"]*"' rust/src/coordinator/v2.rs | tr -d '"' | sort -u); do \
		grep -qF -- "$$r" README.md || { echo "check-docs: README.md is missing v2 route $$r"; ok=0; }; \
	done; \
	for s in '/v1/mux' '/v1/events' 'mux.bad_frame' 'mux.duplicate_id' 'gateway.mux_unrouted' \
			'?topics=' '?since=' 'lagged'; do \
		grep -qF -- "$$s" README.md || { echo "check-docs: README.md is missing streaming doc $$s"; ok=0; }; \
	done; \
	for t in $$(grep -oE 'TOPIC_[A-Z]+: &str = "[a-z]+"' rust/src/mux/events.rs | grep -oE '"[a-z]+"' | tr -d '"'); do \
		grep -qE "^\| .$$t." README.md || { echo "check-docs: README.md topic table is missing '$$t'"; ok=0; }; \
	done; \
	[ $$ok -eq 1 ] && echo "check-docs: README covers every v2 route and the streaming plane"

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy -- -D warnings
