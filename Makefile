# FlexServe-RS build orchestration.
#
#   make artifacts   train the model zoo and AOT-lower it to HLO artifacts
#                    (rust/artifacts/manifest.json + *.hlo.txt) — the input
#                    the Rust server compiles at boot
#   make serve       release-build and start the ensemble server
#   make test        tier-1 verify: release build + tests
#   make bench       build the bench harness and smoke it against an
#                    in-process echo target (no artifacts needed); point
#                    it at a live server with BENCH_FLAGS='--addr ...'
#   make gateway-smoke  device-free gateway cycle: stickiness, kill,
#                    ejection, rerouting over in-process echo replicas
#   make chaos-smoke device-free failure-containment cycle under a seeded
#                    chaos plane: injected panics + connection drops,
#                    breaker trip/recover, supervisor respawns
#   make check-docs  fail if the /v2 routes in rust/src/coordinator/v2.rs
#                    drift from the README "Protocols" matrix
#
# `artifacts` needs the python side (jax + the pallas kernels); the Rust
# targets need only cargo. Device-backed Rust tests self-skip when
# artifacts are missing.

PYTHON ?= python3
ARTIFACTS ?= rust/artifacts

BENCH_FLAGS ?= --echo --connections 4 --duration-secs 3

.PHONY: artifacts serve test bench gateway-smoke chaos-smoke check-docs fmt clippy

artifacts:
	cd python/compile && $(PYTHON) aot.py --out ../../$(ARTIFACTS)

serve:
	cd rust && cargo run --release -- serve

test:
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && cargo run --release -- bench $(BENCH_FLAGS) --out ../BENCH_serve.json
	@echo "wrote BENCH_serve.json"

gateway-smoke:
	cd rust && cargo run --release -- gateway-smoke

chaos-smoke:
	cd rust && cargo run --release -- chaos-smoke

# Every quoted "/v2..." string in v2.rs is a route pattern (the module
# keeps other /v2 spellings out of string literals); each must appear
# verbatim in the README's Protocols section.
check-docs:
	@ok=1; \
	for r in $$(grep -oE '"/v2[^"]*"' rust/src/coordinator/v2.rs | tr -d '"' | sort -u); do \
		grep -qF -- "$$r" README.md || { echo "check-docs: README.md is missing v2 route $$r"; ok=0; }; \
	done; \
	[ $$ok -eq 1 ] && echo "check-docs: README covers every v2 route in v2.rs"

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy -- -D warnings
